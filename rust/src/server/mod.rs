//! Async serving front-end: a worker thread owns the `Engine`, many
//! concurrent clients stream tokens through channel-based handles.
//!
//! The engine is single-threaded by design — one `step()` loop drives
//! admission, budgeted prefill chunks, and the batched decode. What this
//! module adds is *concurrency at the edges*: `AsyncServer::spawn` moves
//! the engine (it is `Send` on the default backend build) onto a
//! dedicated worker thread, and any number of `ServerHandle` clones —
//! one per client thread — talk to it over an mpsc control channel.
//!
//! Channel grammar (DESIGN.md §10):
//!
//! * `submit(req)` sends `Ctl::Submit` carrying a one-shot reply channel;
//!   the worker answers with either a fresh per-request stream receiver
//!   (wrapped as a [`TokenStream`]) or the engine's rejection message —
//!   queue-full shedding surfaces as an `Err` on the *submitting* client
//!   only, never as a worker failure.
//! * Each generated token is forwarded to its request's stream as
//!   [`StreamItem::Token`]; the terminal [`StreamItem::Finished`] is sent
//!   exactly once, after which the worker drops the sender and the
//!   stream's iteration ends.
//! * `cancel(id)` (from the handle or the stream) is fire-and-forget; the
//!   cancelled stream still receives `Finished(Cancelled)` — ordering
//!   between an in-flight token and the cancel is the engine's, not the
//!   channel's.
//! * Dropping a [`TokenStream`] mid-generation is detected on the next
//!   token send and auto-cancels the request, so an abandoned client
//!   cannot pin a decode lane or its KV pages.
//! * `shutdown()` returns the engine itself, so tests and benches can
//!   inspect `Engine::metrics` after the last stream closes.
//!
//! The worker parks on the control channel whenever the engine is idle
//! (no busy-waiting between requests) and otherwise drains pending
//! control messages between `step()` calls, so submissions and
//! cancellations land with at most one step of latency.
//!
//! Scaling past one engine, the [`Router`] owns N `AsyncServer` replicas
//! behind one cloneable [`RouterHandle`] with the same submit/cancel
//! surface: requests are placed on the replica with the longest retained
//! prefix match (ties to the shallowest queue — the `placement` module),
//! hot segments migrate between replicas when load shifts, and shedding
//! happens only when every replica is full (DESIGN.md §12). The
//! [`Frontend`] trait abstracts over both handle kinds so the wall-clock
//! replay harness drives either.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::serving::{Engine, StreamEvent};

mod handle;
pub mod placement;
mod router;

pub use handle::{Frontend, ServerHandle, ServerStats, StreamItem, TokenStream};
pub use placement::{choose, Placement, ReplicaProbe};
pub use router::{Router, RouterConfig, RouterHandle, RouterStats, REPLICA_SHIFT};
use handle::Ctl;

/// Lock-free load snapshot one worker publishes for its router: occupancy
/// counters plus the engine's prefix-cache digest
/// (`Engine::prefix_generation`), refreshed after every control drain and
/// engine step. The router reads these to decide whether a cached probe
/// answer is still valid — a control-channel round-trip is only paid when
/// the digest moved or the replica looks overloaded (DESIGN.md §13).
#[derive(Debug, Default)]
pub struct ReplicaLoad {
    active: AtomicUsize,
    queued: AtomicUsize,
    full: AtomicBool,
    digest: AtomicU64,
    alive: AtomicBool,
}

impl ReplicaLoad {
    fn publish(&self, engine: &Engine) {
        self.active.store(engine.active(), Ordering::Relaxed);
        self.queued.store(engine.queue_len(), Ordering::Relaxed);
        self.full.store(engine.queue_full(), Ordering::Relaxed);
        self.digest.store(engine.prefix_generation(), Ordering::Relaxed);
    }

    /// Sequences holding a decode slot at the last publish.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Admission-queue depth at the last publish.
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Whether a submit would shed at the door at the last publish.
    pub fn full(&self) -> bool {
        self.full.load(Ordering::Relaxed)
    }

    /// The prefix-cache digest at the last publish.
    pub fn digest(&self) -> u64 {
        self.digest.load(Ordering::Relaxed)
    }

    /// False once the worker has exited (its publishes are final).
    pub fn alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }
}

/// The worker-thread front-end over an [`Engine`] (see the module docs
/// for the channel grammar). Spawn it with an engine, hand out
/// [`ServerHandle`] clones to client threads, and call
/// [`AsyncServer::shutdown`] to get the engine back.
pub struct AsyncServer {
    ctl: Sender<Ctl>,
    join: JoinHandle<Engine>,
    load: Arc<ReplicaLoad>,
}

impl AsyncServer {
    /// Move `engine` onto a dedicated worker thread and start serving.
    pub fn spawn(engine: Engine) -> AsyncServer {
        AsyncServer::spawn_with(engine, None)
    }

    /// Like [`AsyncServer::spawn`], with a periodic telemetry snapshot:
    /// every `metrics_interval` engine steps the worker logs a one-line
    /// occupancy/throughput summary (`serve --metrics-interval N`).
    pub fn spawn_with(engine: Engine, metrics_interval: Option<usize>) -> AsyncServer {
        let (ctl, rx) = channel();
        let load = Arc::new(ReplicaLoad::default());
        load.alive.store(true, Ordering::Relaxed);
        load.publish(&engine);
        let wload = load.clone();
        let join = std::thread::spawn(move || worker(engine, rx, metrics_interval, wload));
        AsyncServer { ctl, join, load }
    }

    /// A new client handle (cheap to clone, safe to move across threads).
    pub fn handle(&self) -> ServerHandle {
        ServerHandle::new(self.ctl.clone())
    }

    /// The worker's published load snapshot (shared, lock-free) — the
    /// router's digest-cached probing reads this instead of paying a
    /// control-channel round-trip per placement.
    pub fn load(&self) -> Arc<ReplicaLoad> {
        self.load.clone()
    }

    /// Stop the worker and return the engine (with its accumulated
    /// metrics). In-flight requests are torn down: their streams end
    /// without a terminal item.
    pub fn shutdown(self) -> Engine {
        let _ = self.ctl.send(Ctl::Shutdown);
        self.join.join().expect("server worker panicked")
    }
}

/// The worker loop: park while idle, otherwise interleave control
/// messages with engine steps and fan events out to the per-request
/// streams.
fn worker(
    mut engine: Engine,
    rx: Receiver<Ctl>,
    metrics_interval: Option<usize>,
    load: Arc<ReplicaLoad>,
) -> Engine {
    let mut streams: HashMap<u64, Sender<StreamItem>> = HashMap::new();
    let mut disconnected = false;
    let mut steps: usize = 0;
    'serve: loop {
        let mut pending: Vec<Ctl> = Vec::new();
        if engine.is_idle() {
            if disconnected {
                // no work and no possible source of work: every handle
                // (and every stream's embedded handle) is gone
                break;
            }
            match rx.recv() {
                Ok(msg) => pending.push(msg),
                Err(_) => break,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => pending.push(msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        let dirty = !pending.is_empty();
        for msg in pending {
            match msg {
                Ctl::Submit { req, reply } => match engine.submit(req) {
                    Ok(id) => {
                        let (tx, stream_rx) = channel();
                        streams.insert(id, tx);
                        let _ = reply.send(Ok((id, stream_rx)));
                    }
                    // graceful shedding: the rejection (queue full,
                    // over-horizon, ...) goes back to the one client
                    Err(e) => {
                        let _ = reply.send(Err(e.to_string()));
                    }
                },
                Ctl::Cancel(id) => {
                    engine.cancel(id);
                }
                Ctl::Stats(reply) => {
                    let _ = reply.send(ServerStats {
                        active: engine.active(),
                        queued: engine.queue_len(),
                        kv_allocated_bytes: engine.kv_allocated_bytes(),
                        prefix_retained_bytes: engine.prefix_retained_bytes(),
                        prefix_segments: engine.prefix_segments(),
                    });
                }
                Ctl::Metrics(reply) => {
                    let _ = reply.send(engine.metrics.clone());
                }
                Ctl::MetricsText(reply) => {
                    let _ = reply.send(metrics_text(&engine));
                }
                Ctl::Probe { prompt, reply } => {
                    // one consistent snapshot between steps: the match
                    // length, the load counters, and the digest describe
                    // the same instant, which both the placement rule and
                    // the router's probe memo rely on
                    let _ = reply.send((
                        ReplicaProbe {
                            match_len: engine.prefix_probe(&prompt),
                            active: engine.active(),
                            queued: engine.queue_len(),
                            full: engine.queue_full(),
                        },
                        engine.prefix_generation(),
                    ));
                }
                Ctl::TraceSnapshot(reply) => {
                    let _ = reply.send(engine.tracer().snapshot());
                }
                Ctl::ExportPrefix { prompt, reply } => {
                    let _ = reply.send(engine.export_prefix(&prompt));
                }
                Ctl::ImportPrefix { prefix, reply } => {
                    let adopted = engine.adopt_prefix(*prefix);
                    // adoption bumps the digest: republish before the
                    // reply so the importer's next probe can't hit a
                    // stale memo entry
                    load.publish(&engine);
                    let _ = reply.send(adopted);
                }
                Ctl::Shutdown => break 'serve,
            }
        }
        load.publish(&engine);
        if !engine.is_idle() || dirty {
            // a step on an idle engine is still needed after control
            // traffic: cancellations of queued requests produce their
            // terminal events without any slot running
            match engine.step() {
                Ok(events) => {
                    // publish BEFORE dispatching the step's events: a
                    // client that observes a `Finished` item and probes
                    // must see the digest the finishing retain bumped,
                    // or a memoized probe could serve a stale match
                    load.publish(&engine);
                    dispatch(&mut engine, &mut streams, events);
                }
                Err(_) => break, // backend failure: streams end item-less
            }
            // responses were already streamed event-by-event; drop the
            // accumulated duplicates so a long-lived server stays flat
            engine.take_finished();
            steps += 1;
            if let Some(n) = metrics_interval {
                if n > 0 && steps % n == 0 {
                    crate::info!(
                        "serve: step={steps} active={} queued={} tokens={} kv_bytes={} prefix_hits={}",
                        engine.active(),
                        engine.queue_len(),
                        engine.metrics.generated_tokens,
                        engine.kv_allocated_bytes(),
                        engine.metrics.prefix_hits,
                    );
                }
            }
        }
    }
    // final publish, then mark the worker gone: a router that reads a
    // dead replica's load must fall back to a real (failing) probe
    load.publish(&engine);
    load.alive.store(false, Ordering::Relaxed);
    engine
}

/// Render the engine's full metrics registry plus the worker's live
/// occupancy gauges in the Prometheus text exposition format. With
/// tracing enabled, the scrape also carries the ring-loss counter and
/// live SLO burn-rate gauges folded from the ring (DESIGN.md §13).
fn metrics_text(engine: &Engine) -> String {
    let mut reg = engine.metrics.registry();
    reg.gauge("puzzle_active_lanes", "Sequences currently holding a decode slot", engine.active() as f64);
    reg.gauge("puzzle_queue_depth", "Requests waiting in the admission queue", engine.queue_len() as f64);
    reg.gauge(
        "puzzle_kv_allocated_bytes",
        "Bytes of the paged KV pool currently allocated",
        engine.kv_allocated_bytes() as f64,
    );
    reg.gauge(
        "puzzle_prefix_retained_bytes",
        "Allocated bytes held by retained prefix segments",
        engine.prefix_retained_bytes() as f64,
    );
    reg.gauge(
        "puzzle_prefix_segments",
        "Retained prefix segments currently held",
        engine.prefix_segments() as f64,
    );
    let tracer = engine.tracer();
    if tracer.enabled() {
        reg.counter(
            "puzzle_trace_dropped_events",
            "Trace-ring records overwritten because the ring was full",
            tracer.dropped() as f64,
        );
        let log = tracer.snapshot();
        let records = crate::obs::slo::fold_requests(&[&log]);
        let profiles = crate::obs::slo::burn_profiles(tracer.is_virtual());
        let rates = crate::obs::slo::burn_rates(&records, &profiles, tracer.now_us());
        crate::obs::slo::register_gauges(&mut reg, &rates);
    }
    reg.render()
}

/// Forward one step's events to the per-request streams. A send failure
/// means the client dropped its `TokenStream`: the request is cancelled
/// so it stops burning lane time and KV pages (its `Finished(Cancelled)`
/// event then finds no stream and is dropped on the floor).
fn dispatch(engine: &mut Engine, streams: &mut HashMap<u64, Sender<StreamItem>>, events: Vec<StreamEvent>) {
    for ev in events {
        match ev {
            StreamEvent::Token { id, tok } => {
                let dead = match streams.get(&id) {
                    Some(tx) => tx.send(StreamItem::Token(tok)).is_err(),
                    None => false,
                };
                if dead {
                    streams.remove(&id);
                    engine.cancel(id);
                }
            }
            StreamEvent::Finished { id, reason } => {
                if let Some(tx) = streams.remove(&id) {
                    let _ = tx.send(StreamItem::Finished(reason));
                }
            }
            // rejections never got a stream: the submit reply carried them
            StreamEvent::Rejected { .. } => {}
        }
    }
}
