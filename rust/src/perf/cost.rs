//! Per-block cost accounting: FLOPs, bytes, params, KV-cache — and the
//! scenario-level throughput estimates the MIP consumes.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use crate::config::Manifest;
use crate::runtime::{val_f32, val_i32, Backend, Value};
use crate::util::Timer;

use super::hw::HwProfile;

/// An inference scenario (paper Table 3 rows): prefill length, decode
/// length, batch size.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    /// Prefill (prompt) length.
    pub prefill: usize,
    /// Generated tokens per sequence.
    pub decode: usize,
    /// Concurrent sequences.
    pub batch: usize,
}

impl Scenario {
    /// Short label, e.g. "2048/2048@b64".
    pub fn name(&self) -> String {
        format!("{}/{}@b{}", self.prefill, self.decode, self.batch)
    }

    /// Total tokens processed across the batch.
    pub fn total_tokens(&self) -> usize {
        self.batch * (self.prefill + self.decode)
    }
}

/// Static resource profile of one subblock variant (per layer; layers are
/// shape-identical so costs are layer-independent, as in the paper's
/// per-variant measurement table).
#[derive(Debug, Clone, Default)]
pub struct BlockCost {
    /// parameter count
    pub params: f64,
    /// KV-cache bytes per sequence position (0 for non-attention blocks)
    pub kv_bytes_per_tok: f64,
    /// matmul FLOPs per token, excluding attention's O(s) term
    pub flops_per_tok: f64,
    /// attention score+value FLOPs per token per context position
    pub attn_flops_per_tok_per_ctx: f64,
}

impl BlockCost {
    /// Roofline prefill time for a [batch, s] pass.
    pub fn prefill_time(&self, hw: &HwProfile, batch: usize, s: usize) -> f64 {
        let toks = (batch * s) as f64;
        let flops =
            toks * (2.0 * self.flops_per_tok) + toks * s as f64 * self.attn_flops_per_tok_per_ctx;
        let bytes = self.params * hw.bytes_per_elem + toks * self.kv_bytes_per_tok * hw.bytes_per_elem;
        hw.op_time(flops, bytes)
    }

    /// Roofline time for one decode step at context length `ctx`.
    pub fn decode_step_time(&self, hw: &HwProfile, batch: usize, ctx: usize) -> f64 {
        let toks = batch as f64;
        let flops =
            toks * (2.0 * self.flops_per_tok) + toks * ctx as f64 * self.attn_flops_per_tok_per_ctx;
        // decode reads all weights once per step + the KV cache per sequence
        let bytes = (self.params
            + batch as f64 * ctx as f64 * self.kv_bytes_per_tok)
            * hw.bytes_per_elem;
        hw.op_time(flops, bytes)
    }

    /// Roofline time for a teacher-forced pass over `m` tokens of ONE
    /// sequence at context `ctx` (speculative verification): weights and
    /// the sequence's KV cache are read once and amortized across the m
    /// positions. `decode_step_time` is the batch-of-sequences variant
    /// (KV read per sequence); the two coincide at m = batch = 1.
    pub fn multi_token_pass_time(&self, hw: &HwProfile, m: usize, ctx: usize) -> f64 {
        let toks = m as f64;
        let flops =
            toks * (2.0 * self.flops_per_tok) + toks * ctx as f64 * self.attn_flops_per_tok_per_ctx;
        let bytes = (self.params + ctx as f64 * self.kv_bytes_per_tok) * hw.bytes_per_elem;
        hw.op_time(flops, bytes)
    }

    /// End-to-end scenario time (prefill + all decode steps, mean ctx).
    pub fn scenario_time(&self, hw: &HwProfile, sc: &Scenario) -> f64 {
        let mean_ctx = sc.prefill + sc.decode / 2;
        self.prefill_time(hw, sc.batch, sc.prefill)
            + sc.decode as f64 * self.decode_step_time(hw, sc.batch, mean_ctx)
    }
}

/// Compute the static cost profile of every variant in the manifest.
pub fn block_costs(man: &Manifest) -> (BTreeMap<String, BlockCost>, BTreeMap<String, BlockCost>) {
    let cfg = &man.cfg;
    let (d, dh) = (cfg.d as f64, cfg.head_dim as f64);
    let qd = cfg.qdim() as f64;
    let mut attn = BTreeMap::new();
    for (name, layout) in &man.attn_variants {
        let params = layout.param_count() as f64;
        let cost = if name == "linear" {
            BlockCost { params, flops_per_tok: d * d, ..Default::default() }
        } else {
            let kv = layout.kv_heads as f64;
            BlockCost {
                params,
                kv_bytes_per_tok: 2.0 * kv * dh, // elements; scaled by dtype in roofline
                flops_per_tok: d * qd + 2.0 * d * kv * dh + qd * d,
                attn_flops_per_tok_per_ctx: 4.0 * qd,
            }
        };
        attn.insert(name.clone(), cost);
    }
    attn.insert("noop".into(), BlockCost::default());

    let mut ffn = BTreeMap::new();
    for (name, layout) in &man.ffn_variants {
        let params = layout.param_count() as f64;
        let flops = if name == "linear" { d * d } else { 3.0 * d * layout.i_dim as f64 };
        ffn.insert(name.clone(), BlockCost { params, flops_per_tok: flops, ..Default::default() });
    }
    ffn.insert("noop".into(), BlockCost::default());
    (attn, ffn)
}

/// Sum the per-block costs of a whole architecture (additive across
/// layers) plus the tied LM head into one aggregate `BlockCost`
/// describing a full-model forward of one token. The currency of
/// `specdec::speedup`'s draft-value model and any whole-arch roofline.
pub fn arch_block_cost(man: &Manifest, arch: &Arch) -> BlockCost {
    let (ac, fc) = block_costs(man);
    let cfg = &man.cfg;
    let mut agg = BlockCost {
        params: (cfg.v * cfg.d) as f64,
        flops_per_tok: (cfg.d * cfg.v) as f64,
        ..Default::default()
    };
    for (a, f) in &arch.layers {
        for c in [&ac[&a.name()], &fc[&f.name()]] {
            agg.params += c.params;
            agg.kv_bytes_per_tok += c.kv_bytes_per_tok;
            agg.flops_per_tok += c.flops_per_tok;
            agg.attn_flops_per_tok_per_ctx += c.attn_flops_per_tok_per_ctx;
        }
    }
    agg
}

/// Complete cost table for the MIP: per attention/FFN choice, the runtime
/// under a scenario + memory terms; plus the fixed embed/head costs.
#[derive(Debug, Clone)]
pub struct CostTable {
    /// Hardware profile the times were costed against.
    pub hw: HwProfile,
    /// Scenario the times were costed under.
    pub scenario: Scenario,
    /// variant name -> (scenario seconds, param count, kv bytes/seq)
    pub attn: BTreeMap<String, (f64, f64, f64)>,
    /// FFN variant name -> (scenario seconds, params, kv bytes/seq).
    pub ffn: BTreeMap<String, (f64, f64, f64)>,
    /// embed + head scenario seconds and params (constant per arch)
    pub fixed_secs: f64,
    /// Embed + head parameter count.
    pub fixed_params: f64,
    /// Bytes per weight element at the profile's precision.
    pub bytes_per_param: f64,
}

impl CostTable {
    /// Build from the analytic roofline model.
    pub fn modeled(man: &Manifest, hw: &HwProfile, sc: &Scenario) -> CostTable {
        let (ac, fc) = block_costs(man);
        let cfg = &man.cfg;
        let seq_cap = (sc.prefill + sc.decode) as f64;
        let attn = ac
            .iter()
            .map(|(k, c)| {
                (
                    k.clone(),
                    (
                        c.scenario_time(hw, sc),
                        c.params,
                        c.kv_bytes_per_tok * seq_cap * hw.bytes_per_elem,
                    ),
                )
            })
            .collect();
        let ffn = fc
            .iter()
            .map(|(k, c)| (k.clone(), (c.scenario_time(hw, sc), c.params, 0.0)))
            .collect();
        // LM head: 2*d*v flops per token on prefill + decode tokens
        let head = BlockCost {
            params: (cfg.v * cfg.d) as f64,
            flops_per_tok: (cfg.d * cfg.v) as f64,
            ..Default::default()
        };
        CostTable {
            hw: hw.clone(),
            scenario: *sc,
            attn,
            ffn,
            fixed_secs: head.scenario_time(hw, sc),
            fixed_params: (cfg.v * cfg.d + cfg.d) as f64,
            bytes_per_param: hw.bytes_per_elem,
        }
    }

    /// Build from *measured* executable wall-clock on this machine (the
    /// paper's preferred source). Each variant's prefill and decode
    /// executables are timed with dummy inputs on whatever backend is in
    /// use; the scenario time uses the engine's compiled shapes.
    pub fn measured(be: &dyn Backend, sc: &Scenario, reps: usize) -> Result<CostTable> {
        let man = be.man();
        let cfg = &man.cfg;
        let hw = HwProfile::cpu();
        let mut attn = BTreeMap::new();
        let d = cfg.d;
        let x_pre = val_f32(&[1, cfg.s_prefill, d], &vec![0.01; cfg.s_prefill * d])?;
        let x_dec = val_f32(&[cfg.b_decode, 1, d], &vec![0.01; cfg.b_decode * d])?;
        for (name, layout) in &man.attn_variants {
            let ws: Vec<Value> = layout
                .weights
                .iter()
                .map(|(_, s)| val_f32(s, &vec![0.01; s.iter().product()]))
                .collect::<Result<_>>()?;
            // prefill
            let mut inputs: Vec<&Value> = vec![&x_pre];
            inputs.extend(ws.iter());
            let t_pre = time_exec(be, &format!("attn_{name}_prefill"), &inputs, reps)?;
            // decode
            let t_dec = if name == "linear" {
                let mut di: Vec<&Value> = vec![&x_dec];
                di.extend(ws.iter());
                time_exec(be, &format!("attn_{name}_decode"), &di, reps)?
            } else {
                let kv = layout.kv_heads;
                let cache = val_f32(
                    &[cfg.b_decode, cfg.s_max, kv, cfg.head_dim],
                    &vec![0.0; cfg.b_decode * cfg.s_max * kv * cfg.head_dim],
                )?;
                let pos = val_i32(&[cfg.b_decode], &vec![1; cfg.b_decode])?;
                let mut di: Vec<&Value> = vec![&x_dec, &cache, &cache, &pos];
                di.extend(ws.iter());
                time_exec(be, &format!("attn_{name}_decode"), &di, reps)?
            };
            let secs = sc.batch as f64 * t_pre + sc.decode as f64 * t_dec;
            let kv_bytes = 2.0 * layout.kv_heads as f64
                * cfg.head_dim as f64
                * (sc.prefill + sc.decode) as f64
                * 4.0;
            attn.insert(name.clone(), (secs, layout.param_count() as f64, kv_bytes));
        }
        attn.insert("noop".into(), (0.0, 0.0, 0.0));

        let mut ffn = BTreeMap::new();
        for (name, layout) in &man.ffn_variants {
            let ws: Vec<Value> = layout
                .weights
                .iter()
                .map(|(_, s)| val_f32(s, &vec![0.01; s.iter().product()]))
                .collect::<Result<_>>()?;
            let mut pi: Vec<&Value> = vec![&x_pre];
            pi.extend(ws.iter());
            let t_pre = time_exec(be, &format!("ffn_{name}_prefill"), &pi, reps)?;
            let mut di: Vec<&Value> = vec![&x_dec];
            di.extend(ws.iter());
            let t_dec = time_exec(be, &format!("ffn_{name}_decode"), &di, reps)?;
            let secs = sc.batch as f64 * t_pre + sc.decode as f64 * t_dec;
            ffn.insert(name.clone(), (secs, layout.param_count() as f64, 0.0));
        }
        ffn.insert("noop".into(), (0.0, 0.0, 0.0));

        Ok(CostTable {
            hw,
            scenario: *sc,
            attn,
            ffn,
            fixed_secs: 0.0,
            fixed_params: (cfg.v * cfg.d + cfg.d) as f64,
            bytes_per_param: 4.0,
        })
    }

    /// Modeled scenario seconds for a whole architecture.
    pub fn arch_secs(&self, arch: &Arch) -> f64 {
        self.fixed_secs
            + arch
                .layers
                .iter()
                .map(|(a, f)| self.attn[&a.name()].0 + self.ffn[&f.name()].0)
                .sum::<f64>()
    }

    /// Parameter count of a whole architecture (fixed costs included).
    pub fn arch_params(&self, arch: &Arch) -> f64 {
        self.fixed_params
            + arch
                .layers
                .iter()
                .map(|(a, f)| self.attn[&a.name()].1 + self.ffn[&f.name()].1)
                .sum::<f64>()
    }

    /// KV-cache bytes per sequence for a whole architecture.
    pub fn arch_kv_bytes_per_seq(&self, arch: &Arch) -> f64 {
        arch.layers.iter().map(|(a, _)| self.attn[&a.name()].2).sum()
    }

    /// Total memory footprint for the scenario's batch.
    pub fn arch_memory(&self, arch: &Arch) -> f64 {
        self.arch_params(arch) * self.bytes_per_param
            + self.scenario.batch as f64 * self.arch_kv_bytes_per_seq(arch)
    }

    /// Output tokens per second for this arch under the scenario.
    pub fn arch_throughput(&self, arch: &Arch) -> f64 {
        let secs = self.arch_secs(arch);
        if secs <= 0.0 {
            return f64::INFINITY;
        }
        (self.scenario.batch * self.scenario.decode) as f64 / secs
    }

    /// The space's choices as parallel (attn, ffn) vectors (MIP layout).
    pub fn choices(&self, space: &SearchSpace) -> (Vec<AttnChoice>, Vec<FfnChoice>) {
        (space.attn.clone(), space.ffn.clone())
    }
}

fn time_exec(be: &dyn Backend, name: &str, inputs: &[&Value], reps: usize) -> Result<f64> {
    be.run(name, inputs)?; // warmup (+ compile on AOT backends)
    let t = Timer::start();
    for _ in 0..reps {
        be.run(name, inputs)?;
    }
    Ok(t.secs() / reps as f64)
}

/// Whole-architecture throughput estimate under a hardware model — the
/// quantity on Figure 5's x-axis and Table 3's cells.
pub fn scenario_throughput(man: &Manifest, arch: &Arch, hw: &HwProfile, sc: &Scenario) -> f64 {
    CostTable::modeled(man, hw, sc).arch_throughput(arch)
}

/// Sum of per-layer runtimes relative to parent (Figure 6's bars).
pub fn arch_cost(man: &Manifest, arch: &Arch, hw: &HwProfile, sc: &Scenario) -> Vec<(f64, f64)> {
    let ct = CostTable::modeled(man, hw, sc);
    let parent_attn = ct.attn["gqa_r1"].0;
    let parent_ffn = ct.ffn["r100"].0;
    arch.layers
        .iter()
        .map(|(a, f)| {
            (
                ct.attn[&a.name()].0 / parent_attn.max(1e-12),
                ct.ffn[&f.name()].0 / parent_ffn.max(1e-12),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Manifest, TinyManifest};
    use crate::runtime::RefBackend;

    fn manifest() -> Manifest {
        TinyManifest::synthetic()
    }

    #[test]
    fn cheaper_variants_cost_less() {
        let man = manifest();
        let hw = HwProfile::h100_fp8();
        let sc = Scenario { prefill: 128, decode: 128, batch: 8 };
        let ct = CostTable::modeled(&man, &hw, &sc);
        assert!(ct.attn["gqa_r1"].0 > ct.attn["gqa_r2"].0);
        assert!(ct.attn["gqa_r2"].0 > ct.attn["linear"].0);
        assert!(ct.attn["linear"].0 > ct.attn["noop"].0);
        assert!(ct.ffn["r100"].0 > ct.ffn["r50"].0);
        assert!(ct.ffn["r50"].0 > ct.ffn["r10"].0);
        // kv cache shrinks with fewer kv heads
        assert!(ct.attn["gqa_r1"].2 > ct.attn["gqa_r2"].2);
        assert_eq!(ct.attn["linear"].2, 0.0);
    }

    #[test]
    fn parent_arch_throughput_increases_with_noop_layers() {
        let man = manifest();
        let hw = HwProfile::h100_fp8();
        let sc = Scenario { prefill: 128, decode: 1024, batch: 16 };
        let parent = Arch::parent(man.cfg.n_layers);
        let mut child = parent.clone();
        child.layers[0] = (AttnChoice::NoOp, FfnChoice::NoOp);
        let tp = scenario_throughput(&man, &parent, &hw, &sc);
        let tc = scenario_throughput(&man, &child, &hw, &sc);
        assert!(tc > tp, "skipping a layer must raise modeled throughput");
    }

    #[test]
    fn batch_amortizes_decode_weight_reads() {
        let man = manifest();
        let (ac, _) = block_costs(&man);
        let hw = HwProfile::h100_fp8();
        let c = &ac["gqa_r1"];
        let t1 = c.decode_step_time(&hw, 1, 64);
        let t64 = c.decode_step_time(&hw, 64, 64);
        // 64x the tokens in far less than 64x the time (paper §4.1)
        assert!(t64 < 32.0 * t1);
    }

    #[test]
    fn multi_token_pass_coincides_with_decode_step_at_one() {
        let man = manifest();
        let (ac, _) = block_costs(&man);
        let hw = HwProfile::h100_fp8();
        let c = &ac["gqa_r1"];
        assert_eq!(c.multi_token_pass_time(&hw, 1, 64), c.decode_step_time(&hw, 1, 64));
        // more tokens never cost less, and amortize far below m separate steps
        let t1 = c.multi_token_pass_time(&hw, 1, 64);
        let t5 = c.multi_token_pass_time(&hw, 5, 64);
        assert!(t5 >= t1);
        assert!(t5 <= 5.0 * t1);
    }

    #[test]
    fn arch_block_cost_is_additive_and_shrinks_with_cheaper_blocks() {
        let man = manifest();
        let n = man.cfg.n_layers;
        let parent = arch_block_cost(&man, &Arch::parent(n));
        // head + n_layers * (parent attn + parent ffn)
        let (ac, fc) = block_costs(&man);
        let expect = (man.cfg.v * man.cfg.d) as f64
            + n as f64 * (ac["gqa_r1"].params + fc["r100"].params);
        assert_eq!(parent.params, expect);
        let mut child = Arch::parent(n);
        child.layers[0] = (AttnChoice::Gqa { divisor: 4 }, FfnChoice::Ratio(5));
        let cc = arch_block_cost(&man, &child);
        assert!(cc.params < parent.params);
        assert!(cc.kv_bytes_per_tok < parent.kv_bytes_per_tok);
        assert!(cc.flops_per_tok < parent.flops_per_tok);
    }

    #[test]
    fn measured_costs_on_ref_backend() {
        let be = RefBackend::new(manifest());
        let c = be.man().cfg.clone();
        let sc = Scenario { prefill: c.s_prefill, decode: 8, batch: c.b_decode };
        let ct = CostTable::measured(&be, &sc, 1).unwrap();
        // every variant (plus noop) has a measured entry
        assert!(ct.attn.contains_key("gqa_r1") && ct.attn.contains_key("noop"));
        assert!(ct.ffn.contains_key("r100") && ct.ffn.contains_key("noop"));
        assert!(ct.attn["gqa_r1"].0 > 0.0, "parent attention must cost > 0");
        assert_eq!(ct.attn["noop"].0, 0.0);
        // kv bytes scale with the variant's head count
        assert!(ct.attn["gqa_r1"].2 > ct.attn["gqa_r4"].2);
        assert_eq!(ct.attn["linear"].2, 0.0);
    }
}
