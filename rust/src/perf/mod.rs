//! Resource estimation (paper §4.1): per-block parameter memory, KV-cache
//! memory, and prefill/decode runtimes, fed into the MIP as costs.
//!
//! Two cost sources, matching the paper's methodology:
//!  * **measured** — wall-clock of the actual block executables on this
//!    machine's PJRT CPU backend ("measure directly on target hardware");
//!  * **modeled** — analytic roofline models of the paper's GPUs (H100 /
//!    A100 / RTX 4090, with and without FP8), used to reproduce the
//!    hardware-dependent experiments (Tables 3/6, Figures 5/6/8) whose
//!    hardware we do not have. The roofline captures exactly the effects
//!    the paper calls out: prefill is compute-bound, decode is bandwidth-
//!    bound (weights + KV-cache reads per token), bigger batches amortize
//!    weight reads, FP8 doubles math and halves bytes.

pub mod cost;
pub mod hw;

pub use cost::{arch_block_cost, arch_cost, block_costs, scenario_throughput, BlockCost, CostTable, Scenario};
pub use hw::HwProfile;
