//! Hardware roofline profiles.

#[derive(Debug, Clone)]
/// One accelerator's roofline numbers.
pub struct HwProfile {
    /// Profile name (e.g. "h100_fp8").
    pub name: String,
    /// peak dense matmul throughput, FLOP/s, at the working precision
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s
    pub mem_bw: f64,
    /// bytes per weight/KV element at the working precision
    pub bytes_per_elem: f64,
    /// total device memory in bytes
    pub vram: f64,
    /// attainable fraction of peak for transformer GEMMs
    pub efficiency: f64,
}

impl HwProfile {
    /// NVIDIA H100 SXM with FP8 weights/activations/KV (the paper's main
    /// deployment target; TensorRT-LLM FP8 path).
    pub fn h100_fp8() -> HwProfile {
        HwProfile {
            name: "h100_fp8".into(),
            peak_flops: 1979e12,
            mem_bw: 3.35e12,
            bytes_per_elem: 1.0,
            vram: 80e9,
            efficiency: 0.55,
        }
    }

    /// H100 at FP16 (no FP8) — the fallback the paper contrasts.
    pub fn h100_fp16() -> HwProfile {
        HwProfile {
            name: "h100_fp16".into(),
            peak_flops: 989e12,
            mem_bw: 3.35e12,
            bytes_per_elem: 2.0,
            vram: 80e9,
            efficiency: 0.55,
        }
    }

    /// A100 80GB, FP16 (no FP8 support — the paper's §4.3 example of how
    /// hardware features change the optimal architecture).
    pub fn a100_fp16() -> HwProfile {
        HwProfile {
            name: "a100_fp16".into(),
            peak_flops: 312e12,
            mem_bw: 2.0e12,
            bytes_per_elem: 2.0,
            vram: 80e9,
            efficiency: 0.55,
        }
    }

    /// RTX 4090, FP16 — the consumer-grade target of Table 6.
    pub fn rtx4090_fp16() -> HwProfile {
        HwProfile {
            name: "rtx4090_fp16".into(),
            peak_flops: 165e12,
            mem_bw: 1.008e12,
            bytes_per_elem: 2.0,
            vram: 24e9,
            efficiency: 0.5,
        }
    }

    /// This machine's CPU PJRT backend (used when costs are measured, the
    /// numbers here only seed estimates before measurement).
    pub fn cpu() -> HwProfile {
        HwProfile {
            name: "cpu".into(),
            peak_flops: 3e10,
            mem_bw: 2e10,
            bytes_per_elem: 4.0,
            vram: 8e9,
            efficiency: 0.5,
        }
    }

    /// Look a built-in profile up by name.
    pub fn by_name(name: &str) -> Option<HwProfile> {
        match name {
            "h100_fp8" => Some(Self::h100_fp8()),
            "h100_fp16" => Some(Self::h100_fp16()),
            "a100_fp16" => Some(Self::a100_fp16()),
            "rtx4090_fp16" => Some(Self::rtx4090_fp16()),
            "cpu" => Some(Self::cpu()),
            _ => None,
        }
    }

    /// Roofline time for an op: max(compute time, memory time), seconds.
    /// A zero-work op (a no-op block: no kernel launched) costs nothing.
    pub fn op_time(&self, flops: f64, bytes: f64) -> f64 {
        if flops == 0.0 && bytes == 0.0 {
            return 0.0;
        }
        let t_compute = flops / (self.peak_flops * self.efficiency);
        let t_mem = bytes / self.mem_bw;
        t_compute.max(t_mem) + 2e-6 // per-kernel launch overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_is_memory_bound_prefill_compute_bound() {
        let hw = HwProfile::h100_fp8();
        // decode-ish op: few flops, many bytes
        let t_dec = hw.op_time(1e6, 1e9);
        assert!((t_dec - (1e9 / hw.mem_bw + 2e-6)).abs() / t_dec < 0.01);
        // prefill-ish op: many flops, few bytes
        let t_pre = hw.op_time(1e12, 1e6);
        assert!((t_pre - (1e12 / (hw.peak_flops * hw.efficiency) + 2e-6)).abs() / t_pre < 0.01);
    }

    #[test]
    fn fp8_beats_fp16_on_both_axes() {
        let f8 = HwProfile::h100_fp8();
        let f16 = HwProfile::h100_fp16();
        assert!(f8.op_time(1e12, 0.0) < f16.op_time(1e12, 0.0));
        assert!(f8.bytes_per_elem < f16.bytes_per_elem);
    }
}
