//! Goodput scoring and the `BENCH_workloads.json` report.
//!
//! **Goodput** is the fraction of a trace's intended requests that
//! finished naturally AND met a `(TTFT, ITL)` service-level objective:
//! time-to-first-token within `ttft_ticks` and every inter-token gap
//! within `itl_ticks`, all in deterministic virtual ticks. Raw tok/s
//! rewards batching everything; goodput only pays for tokens that arrive
//! on time — the serving-level lens Puzzle argues model selection should
//! use. Everything emitted here is a pure function of the replay, so CI
//! can diff two runs byte-for-byte.

use crate::util::{percentile, Json};

use super::driver::{ReqRecord, WorkloadRun};
use super::trace::Trace;

/// A `(TTFT, ITL)` service-level objective, in virtual ticks.
#[derive(Debug, Clone, Copy)]
pub struct SloProfile {
    /// Profile label (`lenient`, `strict`).
    pub name: &'static str,
    /// Time-to-first-token budget, ticks.
    pub ttft_ticks: usize,
    /// Per-gap inter-token budget, ticks.
    pub itl_ticks: usize,
}

impl SloProfile {
    /// Did this request meet the SLO? Rejected / unfinished requests
    /// never do.
    pub fn met_by(&self, r: &ReqRecord) -> bool {
        r.finish.is_some()
            && r.ttft_ticks().is_some_and(|t| t <= self.ttft_ticks)
            && r.max_gap_ticks() <= self.itl_ticks
    }
}

/// The two default profiles: `lenient` (queue waits and chunked prefill
/// tolerated) and `strict` (near-interactive). Strict budgets are
/// component-wise tighter, so strict goodput <= lenient goodput on any
/// run — a structural sanity invariant the CI gate asserts.
pub fn default_profiles() -> [SloProfile; 2] {
    [
        SloProfile { name: "lenient", ttft_ticks: 48, itl_ticks: 6 },
        SloProfile { name: "strict", ttft_ticks: 3, itl_ticks: 1 },
    ]
}

/// `(requests met, fraction of intended)` under one SLO. The denominator
/// is every request the trace *intended* — abandoning a conversation
/// cannot improve goodput.
pub fn goodput(run: &WorkloadRun, slo: &SloProfile) -> (usize, f64) {
    let met = run.records.iter().filter(|r| slo.met_by(r)).count();
    if run.intended == 0 {
        (0, 0.0)
    } else {
        (met, met as f64 / run.intended as f64)
    }
}

/// FNV-1a 64-bit hash of the event log — a compact determinism witness
/// (two runs of the same spec + seed + config must agree).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assemble `BENCH_workloads.json`: trace identity, then one entry per
/// replayed configuration with throughput proxies, latency percentiles
/// (ticks), prefix/speculative counters, and goodput under every SLO.
/// Deliberately excludes wall-clock readings — every field is
/// deterministic for a fixed trace spec, seed, and configuration.
pub fn report_json(trace: &Trace, runs: &[WorkloadRun], slos: &[SloProfile]) -> Json {
    let mut j = Json::obj();
    j.set("trace", Json::str(&trace.name));
    j.set("seed", Json::num(trace.seed as f64));
    j.set("conversations", Json::num(trace.convs.len() as f64));
    j.set("requests", Json::num(trace.requests() as f64));
    let mut configs = Vec::with_capacity(runs.len());
    for run in runs {
        let m = &run.metrics;
        let ttfts: Vec<f64> =
            run.records.iter().filter_map(|r| r.ttft_ticks()).map(|t| t as f64).collect();
        let gaps: Vec<f64> =
            run.records.iter().flat_map(|r| r.gaps.iter().map(|&g| g as f64)).collect();
        let e2es: Vec<f64> = run
            .records
            .iter()
            .filter(|r| r.finish.is_some())
            .map(|r| r.e2e_ticks() as f64)
            .collect();
        let mut c = Json::obj();
        c.set("config", Json::str(&run.config));
        c.set("ticks", Json::num(run.ticks as f64));
        c.set("completed", Json::num(run.completed() as f64));
        c.set("generated_tokens", Json::num(m.generated_tokens as f64));
        let forwards = m.prefills + m.decode_steps + m.spec_fused_passes;
        c.set("forwards", Json::num(forwards as f64));
        c.set("tok_per_forward", Json::num(run.tok_per_forward()));
        c.set("ttft_p50_ticks", Json::num(percentile(&ttfts, 50.0)));
        c.set("ttft_p95_ticks", Json::num(percentile(&ttfts, 95.0)));
        c.set("itl_p50_ticks", Json::num(percentile(&gaps, 50.0)));
        c.set("itl_p95_ticks", Json::num(percentile(&gaps, 95.0)));
        c.set("e2e_p50_ticks", Json::num(percentile(&e2es, 50.0)));
        c.set("e2e_p95_ticks", Json::num(percentile(&e2es, 95.0)));
        c.set("chunked_prefills", Json::num(m.chunked_prefills as f64));
        c.set("prefix_hits", Json::num(m.prefix_hits as f64));
        c.set("prefix_misses", Json::num(m.prefix_misses as f64));
        c.set("prefix_tokens_saved", Json::num(m.prefix_tokens_saved as f64));
        c.set("prefix_gen_hits", Json::num(m.prefix_gen_hits as f64));
        c.set("prefix_gen_tokens_saved", Json::num(m.prefix_gen_tokens_saved as f64));
        c.set("draft_proposed", Json::num(m.draft_proposed as f64));
        c.set("draft_accepted", Json::num(m.draft_accepted as f64));
        c.set("accept_rate", Json::num(m.mean_acceptance()));
        c.set("event_log_fnv", Json::str(&format!("{:016x}", fnv1a64(&run.event_log))));
        let mut slo_arr = Vec::with_capacity(slos.len());
        for slo in slos {
            let (met, frac) = goodput(run, slo);
            let mut g = Json::obj();
            g.set("slo", Json::str(slo.name));
            g.set("ttft_ticks", Json::num(slo.ttft_ticks as f64));
            g.set("itl_ticks", Json::num(slo.itl_ticks as f64));
            g.set("met", Json::num(met as f64));
            g.set("goodput", Json::num(frac));
            slo_arr.push(g);
        }
        c.set("goodput", Json::Arr(slo_arr));
        configs.push(c);
    }
    j.set("configs", Json::Arr(configs));
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{EngineMetrics, FinishReason};

    fn rec(submit: usize, first: usize, gaps: Vec<usize>, finish: Option<FinishReason>) -> ReqRecord {
        let last = first + gaps.iter().sum::<usize>();
        ReqRecord {
            conv: 0,
            turn: 0,
            submit_tick: submit,
            first_tick: finish.map(|_| first),
            last_tick: finish.map(|_| last),
            finish_tick: last,
            gaps,
            gen: vec![9],
            finish,
        }
    }

    fn run_of(records: Vec<ReqRecord>, intended: usize) -> WorkloadRun {
        WorkloadRun {
            config: "plain".into(),
            records,
            intended,
            ticks: 10,
            event_log: String::new(),
            wall_secs: 0.0,
            metrics: EngineMetrics::default(),
        }
    }

    #[test]
    fn goodput_counts_only_on_time_finishes() {
        let slo = SloProfile { name: "t", ttft_ticks: 2, itl_ticks: 1 };
        let records = vec![
            rec(0, 1, vec![1, 1], Some(FinishReason::Eos)), // meets
            rec(0, 5, vec![1], Some(FinishReason::MaxNew)), // ttft blown
            rec(0, 1, vec![1, 3], Some(FinishReason::Eos)), // gap blown
            rec(0, 1, vec![], None),                        // rejected
        ];
        let run = run_of(records, 5); // one intended turn never submitted
        let (met, frac) = goodput(&run, &slo);
        assert_eq!(met, 1);
        assert!((frac - 0.2).abs() < 1e-12, "denominator is intended requests");
    }

    #[test]
    fn strict_profile_is_componentwise_tighter() {
        let [lenient, strict] = default_profiles();
        assert!(strict.ttft_ticks <= lenient.ttft_ticks);
        assert!(strict.itl_ticks <= lenient.itl_ticks);
        // therefore met_by(strict) implies met_by(lenient) for any record
        let r = rec(0, 2, vec![1, 1], Some(FinishReason::Eos));
        assert!(!strict.met_by(&r) || lenient.met_by(&r));
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), fnv1a64("a"));
        assert_ne!(fnv1a64("a"), fnv1a64("b"));
    }
}
