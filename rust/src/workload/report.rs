//! Goodput scoring and the `BENCH_workloads.json` report.
//!
//! **Goodput** is the fraction of a trace's intended requests that
//! finished naturally AND met a `(TTFT, ITL)` service-level objective:
//! time-to-first-token within `ttft_ticks` and every inter-token gap
//! within `itl_ticks`, all in deterministic virtual ticks. Raw tok/s
//! rewards batching everything; goodput only pays for tokens that arrive
//! on time — the serving-level lens Puzzle argues model selection should
//! use. Everything emitted here is a pure function of the replay, so CI
//! can diff two runs byte-for-byte.
//!
//! The same trace can also be replayed in *wall-clock* time against the
//! threaded async front-end (`workload::wallclock`); [`WallRecord`] /
//! [`WallSlo`] / [`wall_goodput`] are the seconds-denominated mirror of
//! the virtual-tick types, so one trace gates both clocks. Wall readings
//! are machine-dependent: CI gates only *relative* wall numbers (chunked
//! vs unchunked), never absolute ones.

use crate::serving::FinishReason;
use crate::util::{percentile, Json};

use super::driver::{ReqRecord, WorkloadRun};
use super::trace::Trace;

/// A `(TTFT, ITL)` service-level objective, in virtual ticks.
#[derive(Debug, Clone, Copy)]
pub struct SloProfile {
    /// Profile label (`lenient`, `strict`).
    pub name: &'static str,
    /// Time-to-first-token budget, ticks.
    pub ttft_ticks: usize,
    /// Per-gap inter-token budget, ticks.
    pub itl_ticks: usize,
}

impl SloProfile {
    /// Did this request meet the SLO? Rejected / unfinished requests
    /// never do.
    pub fn met_by(&self, r: &ReqRecord) -> bool {
        r.finish.is_some()
            && r.ttft_ticks().is_some_and(|t| t <= self.ttft_ticks)
            && r.max_gap_ticks() <= self.itl_ticks
    }
}

/// The two default profiles: `lenient` (queue waits and chunked prefill
/// tolerated) and `strict` (near-interactive). Strict budgets are
/// component-wise tighter, so strict goodput <= lenient goodput on any
/// run — a structural sanity invariant the CI gate asserts.
pub fn default_profiles() -> [SloProfile; 2] {
    [
        SloProfile { name: "lenient", ttft_ticks: 48, itl_ticks: 6 },
        SloProfile { name: "strict", ttft_ticks: 3, itl_ticks: 1 },
    ]
}

/// `(requests met, fraction of intended)` under one SLO. The denominator
/// is every request the trace *intended* — abandoning a conversation
/// cannot improve goodput.
pub fn goodput(run: &WorkloadRun, slo: &SloProfile) -> (usize, f64) {
    let met = run.records.iter().filter(|r| slo.met_by(r)).count();
    if run.intended == 0 {
        (0, 0.0)
    } else {
        (met, met as f64 / run.intended as f64)
    }
}

/// One request's wall-clock latency record from a threaded replay
/// (`workload::wallclock::replay_wall`) — the seconds-denominated mirror
/// of `ReqRecord`. A `ttft_secs` of `None` means the request was shed at
/// submit (or the server died before its first token).
#[derive(Debug, Clone)]
pub struct WallRecord {
    /// Conversation index in the trace.
    pub conv: usize,
    /// Turn index within the conversation.
    pub turn: usize,
    /// Submit-to-first-token, seconds.
    pub ttft_secs: Option<f64>,
    /// Gaps between consecutive generated tokens, seconds.
    pub gaps_secs: Vec<f64>,
    /// Submit-to-terminal, seconds.
    pub e2e_secs: f64,
    /// Generated tokens as streamed (the byte-identity witness against a
    /// synchronous virtual-tick replay of the same trace).
    pub gen: Vec<u32>,
    /// Terminal state; `None` when shed or the server died mid-request.
    pub finish: Option<FinishReason>,
}

impl WallRecord {
    /// The worst inter-token gap, seconds (0.0 with fewer than 2 tokens).
    pub fn max_gap_secs(&self) -> f64 {
        self.gaps_secs.iter().fold(0.0, |a, &g| a.max(g))
    }
}

/// A `(TTFT, ITL)` service-level objective in wall-clock seconds — the
/// async front-end's analog of [`SloProfile`].
#[derive(Debug, Clone, Copy)]
pub struct WallSlo {
    /// Profile label.
    pub name: &'static str,
    /// Time-to-first-token budget, seconds.
    pub ttft_secs: f64,
    /// Per-gap inter-token budget, seconds.
    pub itl_secs: f64,
}

impl WallSlo {
    /// Did this request meet the SLO? Shed / unfinished requests never
    /// do; cancellations count as finished (the client chose to stop).
    pub fn met_by(&self, r: &WallRecord) -> bool {
        r.finish.is_some()
            && r.ttft_secs.is_some_and(|t| t <= self.ttft_secs)
            && r.max_gap_secs() <= self.itl_secs
    }
}

/// Default wall-clock profiles, deliberately generous: absolute wall
/// numbers depend on the machine (the RefBackend interpreter is slow),
/// so these exist to *report* goodput structure, while CI gates only the
/// chunked-vs-unchunked comparison.
pub fn default_wall_profiles() -> [WallSlo; 2] {
    [
        WallSlo { name: "wall_lenient", ttft_secs: 30.0, itl_secs: 5.0 },
        WallSlo { name: "wall_strict", ttft_secs: 1.0, itl_secs: 0.25 },
    ]
}

/// `(requests met, fraction of intended)` under one wall-clock SLO —
/// same denominator rule as [`goodput`]: every request the trace
/// intended, so shedding cannot improve the score.
pub fn wall_goodput(records: &[WallRecord], intended: usize, slo: &WallSlo) -> (usize, f64) {
    let met = records.iter().filter(|r| slo.met_by(r)).count();
    if intended == 0 {
        (0, 0.0)
    } else {
        (met, met as f64 / intended as f64)
    }
}

/// Routing imbalance across a replica fleet: max − min of the
/// per-replica routed-request counts (0 for an empty or single-replica
/// fleet). The router's `puzzle_router_load_skew` gauge and
/// `BENCH_router.json` both report this.
pub fn load_skew(counts: &[u64]) -> u64 {
    match (counts.iter().max(), counts.iter().min()) {
        (Some(max), Some(min)) => max - min,
        _ => 0,
    }
}

/// FNV-1a 64-bit hash of the event log — a compact determinism witness
/// (two runs of the same spec + seed + config must agree).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assemble `BENCH_workloads.json`: trace identity, then one entry per
/// replayed configuration with throughput proxies, latency percentiles
/// (ticks), prefix/speculative counters, and goodput under every SLO.
/// Deliberately excludes wall-clock readings — every field is
/// deterministic for a fixed trace spec, seed, and configuration.
pub fn report_json(trace: &Trace, runs: &[WorkloadRun], slos: &[SloProfile]) -> Json {
    let mut j = Json::obj();
    j.set("trace", Json::str(&trace.name));
    j.set("seed", Json::num(trace.seed as f64));
    j.set("conversations", Json::num(trace.convs.len() as f64));
    j.set("requests", Json::num(trace.requests() as f64));
    let mut configs = Vec::with_capacity(runs.len());
    for run in runs {
        let m = &run.metrics;
        let ttfts: Vec<f64> =
            run.records.iter().filter_map(|r| r.ttft_ticks()).map(|t| t as f64).collect();
        let gaps: Vec<f64> =
            run.records.iter().flat_map(|r| r.gaps.iter().map(|&g| g as f64)).collect();
        let e2es: Vec<f64> = run
            .records
            .iter()
            .filter(|r| r.finish.is_some())
            .map(|r| r.e2e_ticks() as f64)
            .collect();
        let mut c = Json::obj();
        c.set("config", Json::str(&run.config));
        c.set("ticks", Json::num(run.ticks as f64));
        c.set("completed", Json::num(run.completed() as f64));
        c.set("generated_tokens", Json::num(m.generated_tokens as f64));
        let forwards = m.prefills + m.decode_steps + m.spec_fused_passes + m.prefill_chunk_passes;
        c.set("forwards", Json::num(forwards as f64));
        c.set("tok_per_forward", Json::num(run.tok_per_forward()));
        c.set("ttft_p50_ticks", Json::num(percentile(&ttfts, 50.0)));
        c.set("ttft_p95_ticks", Json::num(percentile(&ttfts, 95.0)));
        c.set("itl_p50_ticks", Json::num(percentile(&gaps, 50.0)));
        c.set("itl_p95_ticks", Json::num(percentile(&gaps, 95.0)));
        c.set("e2e_p50_ticks", Json::num(percentile(&e2es, 50.0)));
        c.set("e2e_p95_ticks", Json::num(percentile(&e2es, 95.0)));
        c.set("chunked_prefills", Json::num(m.chunked_prefills as f64));
        c.set("prefix_hits", Json::num(m.prefix_hits as f64));
        c.set("prefix_misses", Json::num(m.prefix_misses as f64));
        c.set("prefix_tokens_saved", Json::num(m.prefix_tokens_saved as f64));
        c.set("prefix_gen_hits", Json::num(m.prefix_gen_hits as f64));
        c.set("prefix_gen_tokens_saved", Json::num(m.prefix_gen_tokens_saved as f64));
        c.set("draft_proposed", Json::num(m.draft_proposed as f64));
        c.set("draft_accepted", Json::num(m.draft_accepted as f64));
        c.set("accept_rate", Json::num(m.mean_acceptance()));
        c.set("event_log_fnv", Json::str(&format!("{:016x}", fnv1a64(&run.event_log))));
        let mut slo_arr = Vec::with_capacity(slos.len());
        for slo in slos {
            let (met, frac) = goodput(run, slo);
            let mut g = Json::obj();
            g.set("slo", Json::str(slo.name));
            g.set("ttft_ticks", Json::num(slo.ttft_ticks as f64));
            g.set("itl_ticks", Json::num(slo.itl_ticks as f64));
            g.set("met", Json::num(met as f64));
            g.set("goodput", Json::num(frac));
            slo_arr.push(g);
        }
        c.set("goodput", Json::Arr(slo_arr));
        configs.push(c);
    }
    j.set("configs", Json::Arr(configs));
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{EngineMetrics, FinishReason};

    fn rec(submit: usize, first: usize, gaps: Vec<usize>, finish: Option<FinishReason>) -> ReqRecord {
        let last = first + gaps.iter().sum::<usize>();
        ReqRecord {
            conv: 0,
            turn: 0,
            submit_tick: submit,
            first_tick: finish.map(|_| first),
            last_tick: finish.map(|_| last),
            finish_tick: last,
            gaps,
            gen: vec![9],
            finish,
        }
    }

    fn run_of(records: Vec<ReqRecord>, intended: usize) -> WorkloadRun {
        WorkloadRun {
            config: "plain".into(),
            records,
            intended,
            ticks: 10,
            event_log: String::new(),
            wall_secs: 0.0,
            metrics: EngineMetrics::default(),
        }
    }

    #[test]
    fn goodput_counts_only_on_time_finishes() {
        let slo = SloProfile { name: "t", ttft_ticks: 2, itl_ticks: 1 };
        let records = vec![
            rec(0, 1, vec![1, 1], Some(FinishReason::Eos)), // meets
            rec(0, 5, vec![1], Some(FinishReason::MaxNew)), // ttft blown
            rec(0, 1, vec![1, 3], Some(FinishReason::Eos)), // gap blown
            rec(0, 1, vec![], None),                        // rejected
        ];
        let run = run_of(records, 5); // one intended turn never submitted
        let (met, frac) = goodput(&run, &slo);
        assert_eq!(met, 1);
        assert!((frac - 0.2).abs() < 1e-12, "denominator is intended requests");
    }

    #[test]
    fn strict_profile_is_componentwise_tighter() {
        let [lenient, strict] = default_profiles();
        assert!(strict.ttft_ticks <= lenient.ttft_ticks);
        assert!(strict.itl_ticks <= lenient.itl_ticks);
        // therefore met_by(strict) implies met_by(lenient) for any record
        let r = rec(0, 2, vec![1, 1], Some(FinishReason::Eos));
        assert!(!strict.met_by(&r) || lenient.met_by(&r));
    }

    #[test]
    fn wall_goodput_mirrors_the_tick_rules() {
        let slo = WallSlo { name: "t", ttft_secs: 0.5, itl_secs: 0.1 };
        let wrec = |ttft: Option<f64>, gaps: Vec<f64>, finish: Option<FinishReason>| WallRecord {
            conv: 0,
            turn: 0,
            ttft_secs: ttft,
            gaps_secs: gaps,
            e2e_secs: 1.0,
            gen: vec![9],
            finish,
        };
        let records = vec![
            wrec(Some(0.2), vec![0.05, 0.08], Some(FinishReason::Eos)), // meets
            wrec(Some(0.9), vec![0.05], Some(FinishReason::MaxNew)),    // ttft blown
            wrec(Some(0.2), vec![0.05, 0.3], Some(FinishReason::Eos)),  // gap blown
            wrec(None, vec![], None),                                   // shed
        ];
        assert_eq!(records[2].max_gap_secs(), 0.3);
        let (met, frac) = wall_goodput(&records, 5, &slo);
        assert_eq!(met, 1);
        assert!((frac - 0.2).abs() < 1e-12, "denominator is intended requests");
        assert_eq!(wall_goodput(&[], 0, &slo), (0, 0.0), "empty trace guards the division");
    }

    #[test]
    fn default_wall_profiles_are_componentwise_ordered() {
        let [lenient, strict] = default_wall_profiles();
        assert!(strict.ttft_secs <= lenient.ttft_secs);
        assert!(strict.itl_secs <= lenient.itl_secs);
    }

    #[test]
    fn load_skew_is_max_minus_min() {
        assert_eq!(load_skew(&[]), 0);
        assert_eq!(load_skew(&[5]), 0);
        assert_eq!(load_skew(&[3, 3, 3, 3]), 0, "balanced fleet");
        assert_eq!(load_skew(&[7, 1, 4, 0]), 7);
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), fnv1a64("a"));
        assert_ne!(fnv1a64("a"), fnv1a64("b"));
    }
}
