//! Trace-driven workload harness: seeded traces, closed-loop replay,
//! goodput SLO scoring (DESIGN.md §9).
//!
//! Puzzle's thesis is that *deployment* metrics should drive model
//! selection, and batching/caching/speculation wins only show up under
//! representative request mixes — a one-shot tok/s bench cannot see a
//! prefix cache's multi-turn hit rate or a queue-induced TTFT stall.
//! This module turns that into something CI can falsify:
//!
//! * `trace` — deterministic workload generation: arrival processes
//!   (Poisson, bursty ON/OFF), request mixes (chat, long-context,
//!   shared-system-prompt, speculative), and multi-turn conversations
//!   whose turn N+1 prompt extends turn N's prompt **and completion**.
//! * `driver` — replays a trace against a `Server` (plain `Engine`,
//!   prefix-cache `Engine`, or speculative `SpecBatch`) on a virtual
//!   tick clock, recording per-request TTFT / inter-token gaps / e2e in
//!   ticks plus a byte-reproducible event log.
//! * `report` — goodput under `(TTFT, ITL)` SLO profiles and the
//!   `BENCH_workloads.json` emitter the CI gate consumes, plus the
//!   wall-clock mirror types (`WallRecord` / `WallSlo` / `wall_goodput`)
//!   scored in seconds.
//! * `wallclock` (default backend build only) — the same closed-loop
//!   replay in *real* time against any `server::Frontend` (a
//!   single-engine `ServerHandle` or a multi-replica `RouterHandle`),
//!   one client thread per conversation, with closed- or open-loop
//!   arrival pacing (`Pacing` — open pacing bills latency from the
//!   scheduled arrival, so bursty-overload queueing counts against the
//!   SLO), and the `BENCH_serving_async.json` emitter gating
//!   chunked-vs-unchunked TTFT plus byte identity.
//!
//! The multi-turn mix is the reason this PR also taught the engine to
//! retain prefix segments over *generated* tokens at sequence finish:
//! without that, turn N+1 re-prefills turn N's completion and the
//! prefix cache's `prefix_gen_hits` stays zero.

pub mod driver;
pub mod report;
pub mod trace;
// Wall-clock replay drives the async front-end, which needs the `Send`
// engine of the default backend build (see `crate::server`).
#[cfg(not(feature = "pjrt"))]
pub mod wallclock;

pub use driver::{replay, ReqRecord, Server, WorkloadRun};
pub use report::{
    default_profiles, default_wall_profiles, fnv1a64, goodput, load_skew, report_json,
    wall_goodput, SloProfile, WallRecord, WallSlo,
};
pub use trace::{Arrival, Conversation, MixKind, Trace, TraceSpec, Turn};
#[cfg(not(feature = "pjrt"))]
pub use wallclock::{
    replay_wall, replay_wall_paced, wall_report_json, wall_run_json, Pacing, WallRun,
};
