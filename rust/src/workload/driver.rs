//! Closed-loop trace replay against a serving engine.
//!
//! The driver advances a **virtual clock**: one tick = one engine step
//! (or one speculative round), so every latency in the output is a
//! deterministic tick count, not a wall-clock reading — the same trace,
//! seed, and engine configuration reproduce the event log and every
//! metric byte-for-byte (asserted in the integration tests). Wall-clock
//! throughput is measured separately and reported only on stdout.
//!
//! Multi-turn conversations are stitched **closed-loop**: turn N+1's
//! prompt is turn N's full prompt + completion (trailing EOS stripped
//! by [`strip_trailing_eos`]) + the new user tokens. Against a
//! prefix-cache engine those prompts land on segments retained at the
//! previous turn's *finish* — the generated-token retention rule of
//! DESIGN.md §9. The wall-clock replay (`workload::wallclock`) applies
//! the identical rule, which is what makes its transcripts comparable
//! against this driver's byte-for-byte — single engine or routed fleet
//! alike.
//!
//! The same virtual clock doubles as a fleet timebase: sharing one
//! `Arc<Clock>` across the router's and every replica's tracer
//! (`Tracer::with_clock`) makes the merged trace (`obs::merge_fleet`)
//! deterministic down to the byte, which is how the fleet-trace tests
//! pin exact span tilings without touching wall time.

use std::collections::HashMap;
use std::fmt::Write as _;

use anyhow::{anyhow, Result};

use crate::data::world::EOS;
use crate::obs::Tracer;
use crate::serving::{Engine, EngineMetrics, FinishReason, GenRequest, StreamEvent};
use crate::specdec::{SpecBatch, SpecRequest};
use crate::util::Timer;

use super::trace::Trace;

/// Strip one trailing EOS token from a completion before stitching it
/// into the conversation's next prompt. Both the virtual-tick driver and
/// the wall-clock replay (`workload::wallclock`) stitch through this one
/// function, which is what keeps their multi-turn transcripts
/// byte-comparable.
pub fn strip_trailing_eos(gen: &mut Vec<u32>) {
    if gen.last() == Some(&EOS) {
        gen.pop();
    }
}

/// The serving configuration a trace replays against — a plain or
/// prefix-cache `Engine`, or a speculative `SpecBatch` (drafter +
/// verifier), all driven one tick at a time through the same loop.
pub enum Server<'a> {
    /// A continuous-batching engine (`step()` per tick).
    Engine(&'a mut Engine),
    /// A speculative batch (one draft/verify round per tick).
    Spec(&'a mut SpecBatch),
}

impl Server<'_> {
    fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> Result<u64> {
        match self {
            Server::Engine(e) => e.submit(GenRequest::new(prompt, max_new)),
            Server::Spec(s) => s.submit(SpecRequest::new(prompt, max_new)),
        }
    }

    fn tick(&mut self) -> Result<Vec<StreamEvent>> {
        match self {
            Server::Engine(e) => e.step(),
            Server::Spec(s) => s.tick(),
        }
    }

    fn is_idle(&self) -> bool {
        match self {
            Server::Engine(e) => e.is_idle(),
            Server::Spec(s) => s.is_idle(),
        }
    }

    /// The engine metrics this replay accumulates into (the parent
    /// engine's, for a speculative server).
    pub fn metrics(&self) -> &EngineMetrics {
        match self {
            Server::Engine(e) => &e.metrics,
            Server::Spec(s) => s.parent_metrics(),
        }
    }

    /// The server's lifecycle tracer (the parent engine's, for a
    /// speculative server). The replay loop stamps it with the virtual
    /// tick so trace timestamps match the scored latencies exactly.
    pub fn tracer(&self) -> &Tracer {
        match self {
            Server::Engine(e) => e.tracer(),
            Server::Spec(s) => s.tracer(),
        }
    }
}

/// Per-request latency record, in virtual ticks.
#[derive(Debug, Clone)]
pub struct ReqRecord {
    /// Conversation index in the trace.
    pub conv: usize,
    /// Turn index within the conversation.
    pub turn: usize,
    /// Tick the request was submitted on.
    pub submit_tick: usize,
    /// Tick the first generated token landed on (`None`: rejected, or
    /// finished without emitting — cannot happen for accepted requests).
    pub first_tick: Option<usize>,
    /// Tick of the most recent token (internal cursor for gap tracking).
    pub last_tick: Option<usize>,
    /// Tick the terminal event landed on.
    pub finish_tick: usize,
    /// Inter-token gaps, one per token after the first (ticks; 0 when a
    /// speculative round commits several tokens at once).
    pub gaps: Vec<usize>,
    /// The generated tokens (the driver stitches these into the
    /// conversation's next prompt).
    pub gen: Vec<u32>,
    /// Terminal reason; `None` means the submit was rejected.
    pub finish: Option<FinishReason>,
}

impl ReqRecord {
    /// Time to first token, ticks (`None` until one lands).
    pub fn ttft_ticks(&self) -> Option<usize> {
        self.first_tick.map(|t| t - self.submit_tick)
    }

    /// Worst inter-token gap, ticks (0 for single-token completions).
    pub fn max_gap_ticks(&self) -> usize {
        self.gaps.iter().copied().max().unwrap_or(0)
    }

    /// Submit-to-finish latency, ticks.
    pub fn e2e_ticks(&self) -> usize {
        self.finish_tick - self.submit_tick
    }
}

/// One trace replayed against one server configuration.
#[derive(Debug, Clone)]
pub struct WorkloadRun {
    /// Configuration label (`plain`, `prefix_cache`, `speculative`).
    pub config: String,
    /// Per-request records, in submit order.
    pub records: Vec<ReqRecord>,
    /// Requests the trace intended (the goodput denominator — a rejected
    /// or never-submitted turn counts against goodput).
    pub intended: usize,
    /// Virtual ticks the replay took.
    pub ticks: usize,
    /// Deterministic text log of every submit/token/finish event.
    pub event_log: String,
    /// Wall seconds inside the replay loop (stdout reporting only — NOT
    /// deterministic, excluded from BENCH json).
    pub wall_secs: f64,
    /// Snapshot of the server's engine metrics after the replay.
    pub metrics: EngineMetrics,
}

impl WorkloadRun {
    /// Requests that reached a natural finish.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.finish.is_some()).count()
    }

    /// Generated tokens per deterministic forward (prefills + decode
    /// steps + fused speculative passes + budgeted prefill-chunk passes)
    /// — the virtual-clock throughput proxy that, unlike wall tok/s, is
    /// identical across runs.
    pub fn tok_per_forward(&self) -> f64 {
        let fwd = self.metrics.prefills
            + self.metrics.decode_steps
            + self.metrics.spec_fused_passes
            + self.metrics.prefill_chunk_passes;
        if fwd == 0 {
            0.0
        } else {
            self.metrics.generated_tokens as f64 / fwd as f64
        }
    }
}

/// Conversation replay cursor.
struct ConvState {
    /// Prompt context so far (previous prompt + completion).
    context: Vec<u32>,
    next_turn: usize,
    /// Tick the next turn may submit on (start tick, then finish tick +
    /// think time).
    ready_at: usize,
    /// In-flight request's record index, if any.
    running: Option<usize>,
}

/// Replay `trace` against `server`, one virtual tick at a time, and
/// score every request's TTFT / inter-token gaps / e2e in ticks.
/// Conversations are closed-loop: a turn submits only after the previous
/// turn's completion landed (plus its think time), with the completion
/// stitched into the prompt. A rejected submit abandons the rest of that
/// conversation; the abandoned turns still count against goodput.
pub fn replay(trace: &Trace, server: &mut Server, config: &str) -> Result<WorkloadRun> {
    let timer = Timer::start();
    let mut convs: Vec<ConvState> = trace
        .convs
        .iter()
        .map(|c| ConvState { context: Vec::new(), next_turn: 0, ready_at: c.start, running: None })
        .collect();
    let mut records: Vec<ReqRecord> = Vec::new();
    let mut by_id: HashMap<u64, usize> = HashMap::new();
    let mut log = String::new();
    let mut now = 0usize;
    loop {
        server.tracer().set_virtual_tick(now as u64);
        // submit due turns, in conversation order (deterministic)
        for ci in 0..convs.len() {
            let cs = &mut convs[ci];
            let turns = &trace.convs[ci].turns;
            if cs.running.is_some() || cs.next_turn >= turns.len() || now < cs.ready_at {
                continue;
            }
            let turn = &turns[cs.next_turn];
            let mut prompt = std::mem::take(&mut cs.context);
            prompt.extend(&turn.user);
            let idx = records.len();
            records.push(ReqRecord {
                conv: ci,
                turn: cs.next_turn,
                submit_tick: now,
                first_tick: None,
                last_tick: None,
                finish_tick: now,
                gaps: Vec::new(),
                gen: Vec::new(),
                finish: None,
            });
            match server.submit(prompt.clone(), turn.max_new) {
                Ok(id) => {
                    let _ = writeln!(
                        log,
                        "t={now} submit conv={ci} turn={} id={id} prompt={} max_new={}",
                        cs.next_turn,
                        prompt.len(),
                        turn.max_new
                    );
                    by_id.insert(id, idx);
                    cs.context = prompt;
                    cs.running = Some(idx);
                    cs.next_turn += 1;
                }
                Err(e) => {
                    // the rest of the conversation has no coherent prompt
                    let _ = writeln!(
                        log,
                        "t={now} reject conv={ci} turn={} cause={e}",
                        cs.next_turn
                    );
                    cs.next_turn = turns.len();
                }
            }
        }
        // one virtual tick of serving work
        for ev in server.tick()? {
            match ev {
                StreamEvent::Token { id, tok } => {
                    let Some(&idx) = by_id.get(&id) else { continue };
                    let rec = &mut records[idx];
                    let _ = writeln!(log, "t={now} token id={id} tok={tok}");
                    if let Some(prev) = rec.last_tick {
                        rec.gaps.push(now - prev);
                    } else {
                        rec.first_tick = Some(now);
                    }
                    rec.last_tick = Some(now);
                    rec.gen.push(tok);
                }
                StreamEvent::Finished { id, reason } => {
                    let Some(&idx) = by_id.get(&id) else { continue };
                    let rec = &mut records[idx];
                    let _ = writeln!(log, "t={now} finished id={id} reason={}", reason.as_str());
                    rec.finish = Some(reason);
                    rec.finish_tick = now;
                    let (ci, turn_idx) = (rec.conv, rec.turn);
                    // stitch the completion (sans trailing EOS) into the
                    // conversation context for the next turn
                    let mut gen = rec.gen.clone();
                    strip_trailing_eos(&mut gen);
                    let cs = &mut convs[ci];
                    cs.context.extend(&gen);
                    cs.running = None;
                    if let Some(next) = trace.convs[ci].turns.get(turn_idx + 1) {
                        cs.ready_at = now + 1 + next.think_ticks;
                    }
                }
                StreamEvent::Rejected { id, cause } => {
                    // submit-time rejection: already handled at the call
                    // site (the id never entered by_id); logged for the
                    // deterministic record
                    let _ = writeln!(log, "t={now} rejected id={id} cause={cause}");
                }
            }
        }
        let exhausted = convs
            .iter()
            .zip(&trace.convs)
            .all(|(cs, c)| cs.running.is_none() && cs.next_turn >= c.turns.len());
        if exhausted && server.is_idle() {
            break;
        }
        now += 1;
        if now > 100_000 {
            return Err(anyhow!("workload replay did not converge within 100k ticks"));
        }
    }
    Ok(WorkloadRun {
        config: config.to_string(),
        records,
        intended: trace.requests(),
        ticks: now,
        event_log: log,
        wall_secs: timer.secs(),
        metrics: server.metrics().clone(),
    })
}
