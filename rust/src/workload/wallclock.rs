//! Wall-clock trace replay against the threaded async front-end.
//!
//! `driver::replay` advances a *virtual* clock — one tick per engine
//! step — which makes every latency deterministic but says nothing about
//! real concurrency. This module replays the **same trace** in real
//! time: one client thread per conversation, each talking to the shared
//! [`ServerHandle`], with arrival offsets and think times scaled by a
//! configurable tick duration. The closed-loop stitching rule is
//! byte-for-byte the virtual driver's (turn N+1's prompt = turn N's
//! prompt + completion with the trailing EOS stripped + the new user
//! tokens), so the generated tokens of a wall replay can be compared
//! against a synchronous replay as a byte-identity witness — the
//! budgeted chunked-prefill invariant of DESIGN.md §10.
//!
//! Latencies here are **seconds, not ticks**, and depend on the machine.
//! The report emitter therefore carries both absolute numbers (for
//! humans) and the chunked-vs-unchunked *relative* comparison (the only
//! thing CI gates).

use std::time::{Duration, Instant};

use crate::data::world::EOS;
use crate::server::ServerHandle;
use crate::serving::{EngineMetrics, GenRequest};
use crate::util::{percentile, Json};

use super::report::{default_wall_profiles, wall_goodput, WallRecord};
use super::trace::Trace;

/// One trace replayed in wall-clock time against one server
/// configuration — the seconds-denominated mirror of
/// `driver::WorkloadRun`.
#[derive(Debug, Clone)]
pub struct WallRun {
    /// Configuration label (`unchunked`, `chunked`, ...).
    pub config: String,
    /// Per-request records, grouped by conversation in trace order (turn
    /// order within each conversation).
    pub records: Vec<WallRecord>,
    /// Requests the trace intended (the goodput denominator — shed or
    /// never-submitted turns count against goodput).
    pub intended: usize,
    /// Wall seconds from the first client thread starting to the last
    /// finishing.
    pub wall_secs: f64,
}

impl WallRun {
    /// The generated tokens of every `(conv, turn)` in trace order — the
    /// byte-identity witness. Shed turns contribute their (empty) `gen`,
    /// so two runs compare equal only if they shed identically too.
    pub fn gen_transcript(&self) -> Vec<(usize, usize, Vec<u32>)> {
        self.records.iter().map(|r| (r.conv, r.turn, r.gen.clone())).collect()
    }
}

/// Replay `trace` against a running async server in wall-clock time.
///
/// One client thread per conversation: it sleeps until the
/// conversation's arrival offset (`conv.start` ticks after the common
/// epoch), then walks the turns closed-loop — submit, stream the
/// completion, stitch it into the next prompt, pause `think_ticks`
/// ticks, repeat. A shed submit (`Err` from [`ServerHandle::submit`])
/// records a `ttft_secs: None` entry and abandons the rest of the
/// conversation, exactly like the virtual driver; a server death
/// mid-stream (`finish: None`) abandons it too.
pub fn replay_wall(trace: &Trace, handle: &ServerHandle, tick: Duration, config: &str) -> WallRun {
    let t0 = Instant::now();
    let mut records: Vec<WallRecord> = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = trace
            .convs
            .iter()
            .enumerate()
            .map(|(ci, conv)| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut recs: Vec<WallRecord> = Vec::new();
                    let arrive = t0 + tick.mul_f64(conv.start as f64);
                    std::thread::sleep(arrive.saturating_duration_since(Instant::now()));
                    let mut context: Vec<u32> = Vec::new();
                    for (ti, turn) in conv.turns.iter().enumerate() {
                        if ti > 0 {
                            std::thread::sleep(tick.mul_f64(turn.think_ticks as f64));
                        }
                        let mut prompt = std::mem::take(&mut context);
                        prompt.extend(&turn.user);
                        let submit_at = Instant::now();
                        let stream =
                            match h.submit(GenRequest::new(prompt.clone(), turn.max_new)) {
                                Ok(stream) => stream,
                                Err(_) => {
                                    // shed: record the refusal, abandon the
                                    // conversation (same as the tick driver)
                                    recs.push(WallRecord {
                                        conv: ci,
                                        turn: ti,
                                        ttft_secs: None,
                                        gaps_secs: Vec::new(),
                                        e2e_secs: submit_at.elapsed().as_secs_f64(),
                                        gen: Vec::new(),
                                        finish: None,
                                    });
                                    return recs;
                                }
                            };
                        let mut rec = WallRecord {
                            conv: ci,
                            turn: ti,
                            ttft_secs: None,
                            gaps_secs: Vec::new(),
                            e2e_secs: 0.0,
                            gen: Vec::new(),
                            finish: None,
                        };
                        let mut last_tok: Option<Instant> = None;
                        while let Some(item) = stream.recv() {
                            match item {
                                crate::server::StreamItem::Token(t) => {
                                    let now = Instant::now();
                                    match last_tok {
                                        None => {
                                            rec.ttft_secs =
                                                Some((now - submit_at).as_secs_f64());
                                        }
                                        Some(prev) => {
                                            rec.gaps_secs.push((now - prev).as_secs_f64());
                                        }
                                    }
                                    last_tok = Some(now);
                                    rec.gen.push(t);
                                }
                                crate::server::StreamItem::Finished(reason) => {
                                    rec.finish = Some(reason);
                                    break;
                                }
                            }
                        }
                        rec.e2e_secs = submit_at.elapsed().as_secs_f64();
                        let finished = rec.finish.is_some();
                        let mut gen = rec.gen.clone();
                        recs.push(rec);
                        if !finished {
                            // the server died mid-request: nothing left to
                            // stream to, abandon the conversation
                            return recs;
                        }
                        // closed-loop stitch (trailing EOS stripped), the
                        // same rule as the virtual driver
                        if gen.last() == Some(&EOS) {
                            gen.pop();
                        }
                        context = prompt;
                        context.extend(&gen);
                    }
                    recs
                })
            })
            .collect();
        for j in joins {
            records.extend(j.join().expect("wall-replay client thread panicked"));
        }
    });
    WallRun {
        config: config.to_string(),
        records,
        intended: trace.requests(),
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Latency summary of one wall run as a JSON object (milliseconds).
/// Percentiles are over *finished* requests only; shed or abandoned
/// turns are reported via `completed` / `shed` and the goodput block.
fn wall_run_json(run: &WallRun, metrics: &EngineMetrics) -> Json {
    let done: Vec<&WallRecord> = run.records.iter().filter(|r| r.finish.is_some()).collect();
    let ttfts: Vec<f64> =
        done.iter().filter_map(|r| r.ttft_secs).map(|t| t * 1e3).collect();
    let gaps: Vec<f64> =
        done.iter().flat_map(|r| r.gaps_secs.iter().map(|g| g * 1e3)).collect();
    let e2es: Vec<f64> = done.iter().map(|r| r.e2e_secs * 1e3).collect();
    let gen_tokens: usize = run.records.iter().map(|r| r.gen.len()).sum();
    let goodput = Json::Arr(
        default_wall_profiles()
            .iter()
            .map(|slo| {
                let (met, frac) = wall_goodput(&run.records, run.intended, slo);
                Json::from_pairs(vec![
                    ("slo", Json::str(slo.name)),
                    ("met", Json::num(met as f64)),
                    ("fraction", Json::num(frac)),
                ])
            })
            .collect(),
    );
    Json::from_pairs(vec![
        ("config", Json::str(&run.config)),
        ("intended", Json::num(run.intended as f64)),
        ("completed", Json::num(done.len() as f64)),
        ("shed", Json::num((run.records.len() - done.len()) as f64)),
        ("ttft_p50_ms", Json::num(percentile(&ttfts, 50.0))),
        ("ttft_p95_ms", Json::num(percentile(&ttfts, 95.0))),
        ("itl_p50_ms", Json::num(percentile(&gaps, 50.0))),
        ("itl_p95_ms", Json::num(percentile(&gaps, 95.0))),
        ("e2e_p95_ms", Json::num(percentile(&e2es, 95.0))),
        ("gen_tokens", Json::num(gen_tokens as f64)),
        ("prefill_chunk_passes", Json::num(metrics.prefill_chunk_passes as f64)),
        ("prefill_chunk_tokens", Json::num(metrics.prefill_chunk_tokens as f64)),
        ("wall_secs", Json::num(run.wall_secs)),
        ("goodput", goodput),
    ])
}

/// The `BENCH_serving_async.json` document: trace identity, the
/// byte-identity verdict, and one latency block per configuration (in
/// the order given). The CI gate reads `byte_identical` and compares the
/// configs' `ttft_p95_ms` — chunked prefill must beat unchunked on tail
/// TTFT while producing byte-identical streams.
pub fn wall_report_json(
    trace: &Trace,
    tick: Duration,
    byte_identical: bool,
    runs: &[(&WallRun, &EngineMetrics)],
) -> Json {
    let mut root = Json::obj();
    root.set("bench", Json::str("serving_async"));
    root.set("trace", Json::str(&trace.name));
    root.set("seed", Json::num(trace.seed as f64));
    root.set("conversations", Json::num(trace.convs.len() as f64));
    root.set("requests", Json::num(trace.requests() as f64));
    root.set("tick_ms", Json::num(tick.as_secs_f64() * 1e3));
    root.set("byte_identical", Json::Bool(byte_identical));
    root.set(
        "configs",
        Json::Arr(runs.iter().map(|(run, m)| wall_run_json(run, m)).collect()),
    );
    root
}
