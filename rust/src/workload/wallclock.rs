//! Wall-clock trace replay against the threaded async front-end.
//!
//! `driver::replay` advances a *virtual* clock — one tick per engine
//! step — which makes every latency deterministic but says nothing about
//! real concurrency. This module replays the **same trace** in real
//! time: one client thread per conversation, each talking to a shared
//! [`Frontend`] — a single-engine `ServerHandle` or a multi-replica
//! `RouterHandle`, the replay cannot tell them apart — with arrival
//! offsets and think times scaled by a configurable tick duration. The
//! closed-loop stitching rule is byte-for-byte the virtual driver's
//! (turn N+1's prompt = turn N's prompt + completion with the trailing
//! EOS stripped + the new user tokens), so the generated tokens of a
//! wall replay can be compared against a synchronous replay as a
//! byte-identity witness — the budgeted chunked-prefill invariant of
//! DESIGN.md §10 and the router placement invariant of §12.
//!
//! Two arrival pacings ([`Pacing`]):
//!
//! * **Closed** — turn N+1's clock starts when it is submitted, which
//!   happens after turn N completes plus think time. Under overload this
//!   *hides* queueing delay (coordinated omission: a slow server slows
//!   the arrival process down with it).
//! * **Open** — every turn has a *scheduled* arrival on the trace's tick
//!   grid (conversation start + cumulative think times, independent of
//!   service times), and TTFT/e2e are measured **from the scheduled
//!   arrival**. A turn whose previous completion ran past its schedule
//!   submits late and eats the delay in its own latency — the honest
//!   regime for bursty goodput gating (`bench-router`).
//!
//! Latencies here are **seconds, not ticks**, and depend on the machine.
//! The report emitter therefore carries both absolute numbers (for
//! humans) and *relative* comparisons (chunked vs unchunked, routed vs
//! single-replica — the only things CI gates).
//!
//! To trace a wall replay fleet-wide, build the router's tracer and every
//! replica engine's tracer over **one shared clock**
//! (`Tracer::with_clock` on a single `Arc<Clock>`): all rings then stamp
//! the same timebase and `obs::merge_fleet` can stitch a request's
//! `routed` record (router ring) to its `submitted`/`admitted`/tokens
//! (replica ring) into a tiled cross-process lifecycle. Tracing observes,
//! never steers — the byte-identity witness above holds with rings on or
//! off, which `bench-router --trace-out` re-asserts on every CI run.

use std::time::{Duration, Instant};

use crate::server::Frontend;
use crate::serving::{EngineMetrics, GenRequest};
use crate::util::{percentile, Json};

use super::report::{default_wall_profiles, wall_goodput, WallRecord};
use super::trace::Trace;

/// Arrival pacing for a wall-clock replay (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pacing {
    /// Turn N+1 submits after turn N completes + think time; latencies
    /// are measured from the actual submit instant.
    Closed,
    /// Every turn targets its scheduled arrival on the trace grid;
    /// latencies are measured from the *schedule*, so queueing and
    /// late-submit delay count against the SLO (no coordinated
    /// omission). Stitching still waits for the previous completion.
    Open,
}

/// One trace replayed in wall-clock time against one server
/// configuration — the seconds-denominated mirror of
/// `driver::WorkloadRun`.
#[derive(Debug, Clone)]
pub struct WallRun {
    /// Configuration label (`unchunked`, `chunked`, `routed`, ...).
    pub config: String,
    /// Per-request records, grouped by conversation in trace order (turn
    /// order within each conversation).
    pub records: Vec<WallRecord>,
    /// Requests the trace intended (the goodput denominator — shed or
    /// never-submitted turns count against goodput).
    pub intended: usize,
    /// Wall seconds from the first client thread starting to the last
    /// finishing.
    pub wall_secs: f64,
}

impl WallRun {
    /// The generated tokens of every `(conv, turn)` in trace order — the
    /// byte-identity witness. Shed turns contribute their (empty) `gen`,
    /// so two runs compare equal only if they shed identically too.
    pub fn gen_transcript(&self) -> Vec<(usize, usize, Vec<u32>)> {
        self.records.iter().map(|r| (r.conv, r.turn, r.gen.clone())).collect()
    }
}

/// Replay `trace` against a running front-end in wall-clock time with
/// closed-loop pacing — see [`replay_wall_paced`] for the general form.
pub fn replay_wall<F: Frontend>(trace: &Trace, handle: &F, tick: Duration, config: &str) -> WallRun {
    replay_wall_paced(trace, handle, tick, config, Pacing::Closed)
}

/// Replay `trace` against a running async front-end (a `ServerHandle` or
/// a `RouterHandle` — anything [`Frontend`]) in wall-clock time.
///
/// One client thread per conversation: it walks the turns in order —
/// submit, stream the completion, stitch it into the next prompt —
/// paced by `pacing` (see the module docs). A shed submit (`Err` from
/// `Frontend::submit`) records a `ttft_secs: None` entry and abandons
/// the rest of the conversation, exactly like the virtual driver; a
/// server death mid-stream (`finish: None`) abandons it too.
pub fn replay_wall_paced<F: Frontend>(
    trace: &Trace,
    handle: &F,
    tick: Duration,
    config: &str,
    pacing: Pacing,
) -> WallRun {
    let t0 = Instant::now();
    let mut records: Vec<WallRecord> = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = trace
            .convs
            .iter()
            .enumerate()
            .map(|(ci, conv)| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut recs: Vec<WallRecord> = Vec::new();
                    let mut context: Vec<u32> = Vec::new();
                    // scheduled arrival cursor, ticks on the trace grid
                    // (start + cumulative think; service time excluded)
                    let mut sched = conv.start;
                    for (ti, turn) in conv.turns.iter().enumerate() {
                        if ti > 0 {
                            sched += turn.think_ticks;
                            if pacing == Pacing::Closed {
                                std::thread::sleep(tick.mul_f64(turn.think_ticks as f64));
                            }
                        }
                        let scheduled = t0 + tick.mul_f64(sched as f64);
                        if ti == 0 || pacing == Pacing::Open {
                            std::thread::sleep(
                                scheduled.saturating_duration_since(Instant::now()),
                            );
                        }
                        let mut prompt = std::mem::take(&mut context);
                        prompt.extend(&turn.user);
                        let submit_at = Instant::now();
                        // the latency epoch: open pacing bills from the
                        // schedule so a late submit (previous turn ran
                        // long) or a deep queue cannot hide
                        let arrive_at = match pacing {
                            Pacing::Closed => submit_at,
                            Pacing::Open => scheduled,
                        };
                        let stream =
                            match h.submit(GenRequest::new(prompt.clone(), turn.max_new)) {
                                Ok(stream) => stream,
                                Err(_) => {
                                    // shed: record the refusal, abandon the
                                    // conversation (same as the tick driver)
                                    recs.push(WallRecord {
                                        conv: ci,
                                        turn: ti,
                                        ttft_secs: None,
                                        gaps_secs: Vec::new(),
                                        e2e_secs: arrive_at.elapsed().as_secs_f64(),
                                        gen: Vec::new(),
                                        finish: None,
                                    });
                                    return recs;
                                }
                            };
                        let mut rec = WallRecord {
                            conv: ci,
                            turn: ti,
                            ttft_secs: None,
                            gaps_secs: Vec::new(),
                            e2e_secs: 0.0,
                            gen: Vec::new(),
                            finish: None,
                        };
                        let mut last_tok: Option<Instant> = None;
                        while let Some(item) = stream.recv() {
                            match item {
                                crate::server::StreamItem::Token(t) => {
                                    let now = Instant::now();
                                    match last_tok {
                                        None => {
                                            rec.ttft_secs = Some(
                                                now.saturating_duration_since(arrive_at)
                                                    .as_secs_f64(),
                                            );
                                        }
                                        Some(prev) => {
                                            rec.gaps_secs.push((now - prev).as_secs_f64());
                                        }
                                    }
                                    last_tok = Some(now);
                                    rec.gen.push(t);
                                }
                                crate::server::StreamItem::Finished(reason) => {
                                    rec.finish = Some(reason);
                                    break;
                                }
                            }
                        }
                        rec.e2e_secs = arrive_at.elapsed().as_secs_f64();
                        let finished = rec.finish.is_some();
                        let mut gen = rec.gen.clone();
                        recs.push(rec);
                        if !finished {
                            // the server died mid-request: nothing left to
                            // stream to, abandon the conversation
                            return recs;
                        }
                        // closed-loop stitch (trailing EOS stripped), the
                        // same rule as the virtual driver
                        super::driver::strip_trailing_eos(&mut gen);
                        context = prompt;
                        context.extend(&gen);
                    }
                    recs
                })
            })
            .collect();
        for j in joins {
            records.extend(j.join().expect("wall-replay client thread panicked"));
        }
    });
    WallRun {
        config: config.to_string(),
        records,
        intended: trace.requests(),
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Latency summary of one wall run as a JSON object (milliseconds).
/// Percentiles are over *finished* requests only; shed or abandoned
/// turns are reported via `completed` / `shed` and the goodput block.
/// Public so `bench-router` can embed per-configuration blocks in
/// `BENCH_router.json` with the same schema as `BENCH_serving_async`.
pub fn wall_run_json(run: &WallRun, metrics: &EngineMetrics) -> Json {
    let done: Vec<&WallRecord> = run.records.iter().filter(|r| r.finish.is_some()).collect();
    let ttfts: Vec<f64> =
        done.iter().filter_map(|r| r.ttft_secs).map(|t| t * 1e3).collect();
    let gaps: Vec<f64> =
        done.iter().flat_map(|r| r.gaps_secs.iter().map(|g| g * 1e3)).collect();
    let e2es: Vec<f64> = done.iter().map(|r| r.e2e_secs * 1e3).collect();
    let gen_tokens: usize = run.records.iter().map(|r| r.gen.len()).sum();
    let goodput = Json::Arr(
        default_wall_profiles()
            .iter()
            .map(|slo| {
                let (met, frac) = wall_goodput(&run.records, run.intended, slo);
                Json::from_pairs(vec![
                    ("slo", Json::str(slo.name)),
                    ("met", Json::num(met as f64)),
                    ("fraction", Json::num(frac)),
                ])
            })
            .collect(),
    );
    Json::from_pairs(vec![
        ("config", Json::str(&run.config)),
        ("intended", Json::num(run.intended as f64)),
        ("completed", Json::num(done.len() as f64)),
        ("shed", Json::num((run.records.len() - done.len()) as f64)),
        ("ttft_p50_ms", Json::num(percentile(&ttfts, 50.0))),
        ("ttft_p95_ms", Json::num(percentile(&ttfts, 95.0))),
        ("itl_p50_ms", Json::num(percentile(&gaps, 50.0))),
        ("itl_p95_ms", Json::num(percentile(&gaps, 95.0))),
        ("e2e_p95_ms", Json::num(percentile(&e2es, 95.0))),
        ("gen_tokens", Json::num(gen_tokens as f64)),
        ("prefill_chunk_passes", Json::num(metrics.prefill_chunk_passes as f64)),
        ("prefill_chunk_tokens", Json::num(metrics.prefill_chunk_tokens as f64)),
        ("wall_secs", Json::num(run.wall_secs)),
        ("goodput", goodput),
    ])
}

/// The `BENCH_serving_async.json` document: trace identity, the
/// byte-identity verdict, and one latency block per configuration (in
/// the order given). The CI gate reads `byte_identical` and compares the
/// configs' `ttft_p95_ms` — chunked prefill must beat unchunked on tail
/// TTFT while producing byte-identical streams.
pub fn wall_report_json(
    trace: &Trace,
    tick: Duration,
    byte_identical: bool,
    runs: &[(&WallRun, &EngineMetrics)],
) -> Json {
    let mut root = Json::obj();
    root.set("bench", Json::str("serving_async"));
    root.set("trace", Json::str(&trace.name));
    root.set("seed", Json::num(trace.seed as f64));
    root.set("conversations", Json::num(trace.convs.len() as f64));
    root.set("requests", Json::num(trace.requests() as f64));
    root.set("tick_ms", Json::num(tick.as_secs_f64() * 1e3));
    root.set("byte_identical", Json::Bool(byte_identical));
    root.set(
        "configs",
        Json::Arr(runs.iter().map(|(run, m)| wall_run_json(run, m)).collect()),
    );
    root
}
