//! Seeded, deterministic workload traces: arrival processes, request
//! mixes, and multi-turn conversations.
//!
//! A trace is a list of conversations, each with a start tick drawn from
//! an arrival process and one or more turns. Turn tokens come from the
//! synthetic `data::world` corpus (the same token distributions the
//! models were trained on), sized against the serving geometry so long
//! prompts exercise chunked prefill without overrunning the cache
//! horizon. Everything is a pure function of `TraceSpec` — two calls to
//! `generate` with the same spec yield identical traces, which is what
//! lets the replay harness assert byte-identical event logs.

use crate::data::corpus::{sample_sequence, CorpusMix};
use crate::data::world::World;
use crate::util::Rng;

/// When conversations start, in virtual ticks.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson process: exponential inter-arrival gaps with the given
    /// mean (ticks).
    Poisson {
        /// Mean gap between consecutive conversation starts.
        mean_gap: f64,
    },
    /// ON/OFF bursts: `burst` conversations arrive back-to-back on one
    /// tick, then `idle` quiet ticks before the next burst.
    Bursty {
        /// Conversations per burst.
        burst: usize,
        /// Quiet ticks between bursts.
        idle: usize,
    },
}

impl Arrival {
    /// Start ticks for `n` conversations, non-decreasing.
    pub fn starts(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::with_capacity(n);
        match *self {
            Arrival::Poisson { mean_gap } => {
                let mut t = 0.0f64;
                for _ in 0..n {
                    // inverse-CDF exponential draw; 1 - u keeps ln finite
                    t += -mean_gap * (1.0 - rng.f64()).ln();
                    out.push(t as usize);
                }
            }
            Arrival::Bursty { burst, idle } => {
                let burst = burst.max(1);
                for i in 0..n {
                    out.push((i / burst) * (idle + 1));
                }
            }
        }
        out
    }
}

/// Request-mix families a trace draws its conversations from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixKind {
    /// Short prompt, short completion — interactive chat.
    Chat,
    /// Prompt longer than the prefill window — exercises chunked
    /// (teacher-forced) prefill.
    LongContext,
    /// A common system prompt shared by every conversation plus a short
    /// unique tail — the prefix cache's bread and butter.
    Shared,
    /// Moderate prompt, longer completion — the shape speculative
    /// decoding amortizes best.
    Spec,
    /// Three-turn conversations where each turn's prompt extends the
    /// previous prompt *and* completion — only finish-time retention of
    /// generated tokens can serve these warm.
    MultiTurn,
    /// Round-robin over all of the above.
    Mixed,
}

impl MixKind {
    /// CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            MixKind::Chat => "chat",
            MixKind::LongContext => "longcontext",
            MixKind::Shared => "shared",
            MixKind::Spec => "spec",
            MixKind::MultiTurn => "multiturn",
            MixKind::Mixed => "mixed",
        }
    }

    /// Parse a CLI name (`chat|longcontext|shared|spec|multiturn|mixed`).
    pub fn parse(s: &str) -> Option<MixKind> {
        Some(match s {
            "chat" => MixKind::Chat,
            "longcontext" => MixKind::LongContext,
            "shared" => MixKind::Shared,
            "spec" => MixKind::Spec,
            "multiturn" => MixKind::MultiTurn,
            "mixed" => MixKind::Mixed,
            _ => return None,
        })
    }
}

/// One user turn of a conversation.
#[derive(Debug, Clone)]
pub struct Turn {
    /// Tokens appended to the conversation context for this turn (the
    /// first turn starts with BOS; later turns are bare continuations).
    pub user: Vec<u32>,
    /// Generation budget for this turn (>= 1).
    pub max_new: usize,
    /// Quiet ticks between the previous turn's finish and this submit.
    pub think_ticks: usize,
}

/// One conversation: a start tick plus its turns, replayed closed-loop
/// (turn N+1's prompt is turn N's full prompt + completion + new user
/// tokens — the replay driver stitches completions in as they land).
#[derive(Debug, Clone)]
pub struct Conversation {
    /// Tick at which the first turn may be submitted.
    pub start: usize,
    /// The turns, in order.
    pub turns: Vec<Turn>,
}

/// A fully materialized workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Mix name (report key).
    pub name: String,
    /// Generator seed (report key).
    pub seed: u64,
    /// The conversations to replay.
    pub convs: Vec<Conversation>,
}

impl Trace {
    /// Total request count (one per turn).
    pub fn requests(&self) -> usize {
        self.convs.iter().map(|c| c.turns.len()).sum()
    }
}

/// Trace generator parameters — the whole workload is a deterministic
/// function of this spec plus the serving geometry.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Request-mix family.
    pub mix: MixKind,
    /// Arrival process for conversation start ticks.
    pub arrival: Arrival,
    /// Conversation count.
    pub conversations: usize,
    /// Generator seed: same spec + seed ⇒ identical trace.
    pub seed: u64,
}

impl TraceSpec {
    /// A small default spec for `mix`: 6 conversations, Poisson arrivals
    /// with a 3-tick mean gap.
    pub fn small(mix: MixKind, seed: u64) -> TraceSpec {
        TraceSpec { mix, arrival: Arrival::Poisson { mean_gap: 3.0 }, conversations: 6, seed }
    }

    /// A bursty ON/OFF spec for `mix`: 12 conversations arriving in
    /// back-to-back bursts of 4 with 8 quiet ticks between bursts — the
    /// `bench-router` stress scale. Open-loop pacing over this arrival
    /// pattern is what separates a replicated fleet from a single
    /// engine: each burst lands on several replicas at once instead of
    /// queueing behind one.
    pub fn bursty(mix: MixKind, seed: u64) -> TraceSpec {
        TraceSpec { mix, arrival: Arrival::Bursty { burst: 4, idle: 8 }, conversations: 12, seed }
    }

    /// Materialize the trace against a serving geometry: `vocab_size`
    /// drives token realism, `prefill_window` (`s_prefill`) is what
    /// long-context prompts deliberately exceed, and every conversation
    /// keeps prompt + generation within `horizon` (`s_max`) so nothing
    /// trips the engine's admission checks.
    pub fn generate(&self, vocab_size: u32, prefill_window: usize, horizon: usize) -> Trace {
        let mut rng = Rng::new(self.seed ^ 0x1_7ace);
        let world = World::new(3, vocab_size);
        let mix = CorpusMix::distillation_mix();
        // the shared-system-prompt mix prepends this to every conversation
        let system = sample_sequence(&world, &mix, 11, &mut rng);
        let mut starts = self.arrival.starts(self.conversations, &mut rng);
        let mut convs = Vec::with_capacity(self.conversations);
        for ci in 0..self.conversations {
            let kind = match self.mix {
                MixKind::Mixed => [
                    MixKind::Chat,
                    MixKind::Shared,
                    MixKind::MultiTurn,
                    MixKind::Spec,
                    MixKind::LongContext,
                ][ci % 5],
                k => k,
            };
            let turns = self.turns_for(kind, &world, &mix, &system, prefill_window, horizon, &mut rng);
            convs.push(Conversation { start: starts.remove(0), turns });
        }
        Trace { name: self.mix.name().to_string(), seed: self.seed, convs }
    }

    fn turns_for(
        &self,
        kind: MixKind,
        world: &World,
        mix: &CorpusMix,
        system: &[u32],
        prefill_window: usize,
        horizon: usize,
        rng: &mut Rng,
    ) -> Vec<Turn> {
        match kind {
            MixKind::Chat => {
                let user = sample_sequence(world, mix, rng.range(4, 10), rng);
                vec![Turn { user, max_new: rng.range(4, 9), think_ticks: 0 }]
            }
            MixKind::LongContext => {
                // past the prefill window (chunked ingest), with headroom
                // for the completion under the horizon
                let max_new = rng.range(3, 7);
                let want = prefill_window + rng.range(1, prefill_window / 2 + 2);
                let len = want.min(horizon.saturating_sub(max_new + 2)).max(2);
                let user = sample_sequence(world, mix, len, rng);
                vec![Turn { user, max_new, think_ticks: 0 }]
            }
            MixKind::Shared => {
                let mut user = system.to_vec();
                // sample_sequence leads with BOS; drop it on the tail so
                // the shared prefix is the longest common prefix
                user.extend(&sample_sequence(world, mix, rng.range(3, 7), rng)[1..]);
                vec![Turn { user, max_new: rng.range(4, 9), think_ticks: 0 }]
            }
            MixKind::Spec => {
                let user = sample_sequence(world, mix, rng.range(5, 9), rng);
                vec![Turn { user, max_new: rng.range(8, 11), think_ticks: 0 }]
            }
            MixKind::MultiTurn => {
                // sized so the third turn's prompt (two turns of context
                // plus completions) can exceed the prefill window while
                // prompt + max_new stays under the horizon
                let mut turns = vec![Turn {
                    user: sample_sequence(world, mix, rng.range(5, 8), rng),
                    max_new: rng.range(6, 8),
                    think_ticks: rng.below(3),
                }];
                for _ in 0..2 {
                    turns.push(Turn {
                        user: sample_sequence(world, mix, rng.range(7, 10), rng)[1..].to_vec(),
                        max_new: rng.range(6, 8),
                        think_ticks: rng.below(3),
                    });
                }
                turns
            }
            MixKind::Mixed => unreachable!("mixed resolves to a concrete kind per conversation"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = TraceSpec::small(MixKind::Mixed, 7);
        let a = spec.generate(128, 32, 48);
        let b = spec.generate(128, 32, 48);
        assert_eq!(a.convs.len(), b.convs.len());
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.start, cb.start);
            assert_eq!(ca.turns.len(), cb.turns.len());
            for (ta, tb) in ca.turns.iter().zip(&cb.turns) {
                assert_eq!(ta.user, tb.user);
                assert_eq!(ta.max_new, tb.max_new);
                assert_eq!(ta.think_ticks, tb.think_ticks);
            }
        }
        let c = TraceSpec::small(MixKind::Mixed, 8).generate(128, 32, 48);
        let users = |t: &Trace| {
            t.convs.iter().flat_map(|c| c.turns.iter().flat_map(|t| t.user.clone())).collect::<Vec<_>>()
        };
        assert_ne!(users(&a), users(&c), "a different seed must change the trace");
    }

    #[test]
    fn conversations_respect_the_horizon() {
        for mix in [
            MixKind::Chat,
            MixKind::LongContext,
            MixKind::Shared,
            MixKind::Spec,
            MixKind::MultiTurn,
            MixKind::Mixed,
        ] {
            for seed in 0..4 {
                let trace = TraceSpec::small(mix, seed).generate(128, 32, 48);
                assert_eq!(trace.requests(), trace.convs.iter().map(|c| c.turns.len()).sum());
                for conv in &trace.convs {
                    let total: usize =
                        conv.turns.iter().map(|t| t.user.len() + t.max_new).sum();
                    assert!(total <= 48, "conversation cannot outgrow the horizon: {total}");
                    for turn in &conv.turns {
                        assert!(turn.max_new >= 1);
                        assert!(!turn.user.is_empty());
                    }
                }
            }
        }
    }

    #[test]
    fn multiturn_third_prompt_can_exceed_the_prefill_window() {
        let trace = TraceSpec::small(MixKind::MultiTurn, 7).generate(128, 32, 48);
        // worst case (every turn maxes its budget) the third prompt is
        // users + two full completions; at least one conversation must be
        // able to cross the 32-token prefill window
        let can_cross = trace.convs.iter().any(|c| {
            let users: usize = c.turns.iter().map(|t| t.user.len()).sum();
            let gens: usize = c.turns[..2].iter().map(|t| t.max_new).sum();
            users + gens > 32
        });
        assert!(can_cross, "multiturn sizing must be able to exercise chunked prefill");
    }

    #[test]
    fn arrivals_are_nondecreasing_and_bursty_groups() {
        let mut rng = Rng::new(3);
        let starts = Arrival::Poisson { mean_gap: 2.0 }.starts(16, &mut rng);
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
        let starts = Arrival::Bursty { burst: 3, idle: 4 }.starts(7, &mut rng);
        assert_eq!(starts, vec![0, 0, 0, 5, 5, 5, 10]);
    }

    #[test]
    fn bursty_spec_is_deterministic_and_actually_bursts() {
        let spec = TraceSpec::bursty(MixKind::Shared, 7);
        let a = spec.generate(64, 16, 48);
        let b = TraceSpec::bursty(MixKind::Shared, 7).generate(64, 16, 48);
        assert_eq!(a.convs.len(), 12);
        for (ca, cb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(ca.start, cb.start);
            assert_eq!(ca.turns.len(), cb.turns.len());
            for (ta, tb) in ca.turns.iter().zip(&cb.turns) {
                assert_eq!(ta.user, tb.user);
            }
        }
        // bursts of 4 share a start tick; bursts are separated by idle
        let starts: Vec<usize> = a.convs.iter().map(|c| c.start).collect();
        assert_eq!(starts[0], starts[3], "first burst arrives together");
        assert!(starts[4] > starts[3], "quiet gap between bursts");
    }
}
