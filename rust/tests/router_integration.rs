//! Integration tests for the data-parallel router (`server::Router`):
//! the single-replica equivalence oracle (a 1-replica router is byte-
//! identical to a bare `AsyncServer`), routed N-replica fleets matching
//! the single-engine sync replay token for token, the deterministic
//! warm/pin/spill sequence that forces a cross-replica prefix migration,
//! exact migration accounting on both engines (refcounts, retained
//! bytes, double-adopt), mid-migration cancellation leaking no pages,
//! and open-loop pacing preserving byte identity. The router needs
//! `Engine: Send`, so this whole crate is compiled only on the default
//! (non-pjrt) backend build.
#![cfg(not(feature = "pjrt"))]

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::obs::{Clock, Event, Tracer, DEFAULT_RING_CAP};
use puzzle::runtime::{share, Backend, SharedBackend};
use puzzle::server::{AsyncServer, Router, RouterConfig, RouterHandle, REPLICA_SHIFT};
use puzzle::serving::{Engine, EngineConfig, GenRequest};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;
use puzzle::weights::Store;
use puzzle::workload::{
    replay, replay_wall, replay_wall_paced, MixKind, Pacing, Server, Trace, TraceSpec,
};

fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

/// A child with per-layer KV geometry differences (GQA divisors, one
/// linear-attention layer with no KV at all) — the migration payload
/// must slice and re-retain correctly across all of them.
fn variable_arch(be: &dyn Backend, store: &mut Store) -> Arch {
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind, variant: v };
                bld::init_job_weights(be.man(), store, &job, None).unwrap();
            }
        }
    }
    arch
}

/// The replica configuration every router test uses: prefix cache on
/// (the placement signal) and a queue deep enough that shedding never
/// depends on wall timing — shed-vs-served divergence would break the
/// byte-identity comparisons.
fn replica_cfg() -> EngineConfig {
    EngineConfig::new()
        .kv_budget_bytes(16 << 20)
        .page_len(4)
        .max_queue(1024)
        .prefix_cache(true, 8 << 20)
}

fn build_engines(be: &SharedBackend, store: &Store, arch: &Arch, n: usize) -> Vec<Engine> {
    (0..n).map(|_| replica_cfg().build(be.clone(), store, arch).unwrap()).collect()
}

fn transcript_of(records: &[puzzle::workload::WallRecord]) -> BTreeMap<(usize, usize), Vec<u32>> {
    records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect()
}

/// The deterministic virtual-tick replay on one engine: the oracle every
/// wall-clock transcript is compared against.
fn sync_oracle(
    be: &SharedBackend,
    store: &Store,
    arch: &Arch,
    trace: &Trace,
) -> BTreeMap<(usize, usize), Vec<u32>> {
    let mut eng = replica_cfg().build(be.clone(), store, arch).unwrap();
    let run = replay(trace, &mut Server::Engine(&mut eng), "sync_oracle").unwrap();
    run.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect()
}

/// Block until every replica has drained (no active or queued work), so
/// page-accounting assertions see a settled fleet. Cancels are
/// fire-and-forget, so the worker may still be tearing a request down
/// when the cancel call returns.
fn wait_idle(handle: &RouterHandle) {
    for _ in 0..500 {
        let stats = handle.stats().unwrap();
        if stats.replicas.iter().all(|s| s.active == 0 && s.queued == 0) {
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("router replicas did not drain");
}

#[test]
fn one_replica_router_is_byte_identical_to_a_bare_async_server() {
    // the equivalence oracle: the router's placement layer must be
    // invisible when there is nothing to place — same trace, same
    // streams, through a bare AsyncServer and through a 1-replica Router.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(91);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut spec = TraceSpec::small(MixKind::Mixed, 21);
    spec.conversations = 4;
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let want = sync_oracle(&be, &store, &arch, &trace);

    let bare = {
        let server = AsyncServer::spawn(replica_cfg().build(be.clone(), &store, &arch).unwrap());
        let h = server.handle();
        let run = replay_wall(&trace, &h, Duration::from_millis(1), "bare");
        drop(h);
        server.shutdown();
        transcript_of(&run.records)
    };
    assert_eq!(bare, want, "bare AsyncServer must match the sync oracle");

    let router = Router::spawn(build_engines(&be, &store, &arch, 1), RouterConfig::default());
    let h = router.handle();
    let run = replay_wall(&trace, &h, Duration::from_millis(1), "router1");
    let stats = h.stats().unwrap();
    drop(h);
    router.shutdown();
    assert_eq!(transcript_of(&run.records), want, "1-replica router must equal the bare server");
    assert_eq!(stats.routed, vec![trace.requests() as u64], "every request lands on replica 0");
    assert_eq!((stats.migrations, stats.shed), (0, 0), "one replica: nothing to migrate or shed");
}

#[test]
fn routed_fleets_match_the_single_engine_oracle_byte_for_byte() {
    // placement must never steer sampling: a shared-prefix trace routed
    // across 2 and 4 replicas generates exactly the tokens of a fresh
    // single-engine run, whichever replica each request landed on.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(92);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut spec = TraceSpec::small(MixKind::Shared, 13);
    spec.conversations = 6;
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let want = sync_oracle(&be, &store, &arch, &trace);

    for n in [2usize, 4] {
        let router = Router::spawn(build_engines(&be, &store, &arch, n), RouterConfig::default());
        let h = router.handle();
        let run = replay_wall(&trace, &h, Duration::from_millis(1), "routed");
        let stats = h.stats().unwrap();
        drop(h);
        router.shutdown();
        assert_eq!(
            transcript_of(&run.records),
            want,
            "{n}-replica routed transcript must match the single-engine oracle"
        );
        assert_eq!(stats.total_routed(), trace.requests() as u64, "every request was accepted");
        assert_eq!(stats.shed, 0, "a 1024-deep queue per replica never sheds this trace");
        assert_eq!(stats.routed.len(), n);
    }
}

#[test]
fn overloaded_hot_replica_migrates_its_prefix_and_stays_byte_identical() {
    // the acceptance scenario, made deterministic. warm: one request
    // retains the shared prefix on replica 0. pin: a long request routes
    // to replica 0 (longest match) and holds it at the overload depth.
    // spill: the next shared-prefix request must route AWAY from the hot
    // replica, dragging the retained segment along (exactly one
    // migration of the 8-token page-aligned prefix), and its stream must
    // still equal a cold single-engine run. A bursty shared-prefix trace
    // then replays through the same fleet and must match the sync oracle
    // with a positive aggregate hit rate.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(93);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let rcfg = RouterConfig { overload: 1, min_migrate: 1, ..RouterConfig::default() };
    let router = Router::spawn(build_engines(&be, &store, &arch, 4), rcfg);
    let h = router.handle();

    let shared: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14]; // 11 tokens
    let with_tail = |tail: &[u32]| {
        let mut p = shared.clone();
        p.extend_from_slice(tail);
        p
    };

    // warm: all probes tie at match 0 / depth 0, lowest index wins
    let warm = h.submit(GenRequest::new(with_tail(&[20, 21, 22]), 6)).unwrap();
    assert_eq!(warm.id() >> REPLICA_SHIFT, 0, "first request must land on replica 0");
    let (_, warm_finish) = warm.collect();
    assert!(warm_finish.is_some());
    assert!(
        h.stats().unwrap().replicas[0].prefix_segments >= 1,
        "warm's finish must retain the shared prefix on replica 0"
    );

    // pin: replica 0 now has the longest match (8 of the 11 shared
    // tokens, page-aligned) and is idle, so it wins placement — and its
    // in-flight depth reaches the overload threshold
    let pin = h.submit(GenRequest::new(with_tail(&[23, 24, 25]), 24)).unwrap();
    assert_eq!(pin.id() >> REPLICA_SHIFT, 0, "longest match must pin replica 0");

    // spill: replica 0 still has the best match but sits at the
    // overload depth, so placement picks replica 1 and migrates the
    // segment first
    let spill = h.submit(GenRequest::new(with_tail(&[26, 27, 28]), 6)).unwrap();
    assert_eq!(spill.id() >> REPLICA_SHIFT, 1, "overloaded best match must lose the pick");
    let (spill_tokens, spill_finish) = spill.collect();
    assert!(spill_finish.is_some());

    let stats = h.stats().unwrap();
    assert_eq!(stats.migrations, 1, "exactly one cross-replica migration");
    assert_eq!(stats.migrated_tokens, 8, "the page-aligned 8-token shared prefix moved");
    assert_eq!(stats.routed, vec![2, 1, 0, 0]);
    assert_eq!(stats.shed, 0);
    assert!(
        stats.replicas[1].prefix_segments >= 1,
        "replica 1 must hold the adopted segment"
    );

    // the migrated hit is byte-identical to a cold run of the same
    // request on a fresh engine (no cache at all)
    let cold_tokens = {
        let mut eng = EngineConfig::new()
            .kv_budget_bytes(16 << 20)
            .page_len(4)
            .build(be.clone(), &store, &arch)
            .unwrap();
        let id = eng.submit(GenRequest::new(with_tail(&[26, 27, 28]), 6)).unwrap();
        let resp = eng.run_to_completion().unwrap();
        resp.into_iter().find(|r| r.id == id).unwrap().tokens
    };
    assert_eq!(spill_tokens, cold_tokens, "a migrated prefix hit must not change the stream");

    let (_, pin_finish) = pin.collect();
    assert!(pin_finish.is_some());

    // a seeded bursty shared-prefix trace through the (already warm)
    // fleet: still byte-identical to the fresh sync oracle — retained
    // and migrated segments change where KV comes from, never the tokens
    let trace = TraceSpec::bursty(MixKind::Shared, 17).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let want = sync_oracle(&be, &store, &arch, &trace);
    let run = replay_wall_paced(&trace, &h, Duration::from_millis(1), "routed", Pacing::Open);
    assert_eq!(transcript_of(&run.records), want, "routed bursty replay must match the oracle");

    let agg = h.aggregate_metrics().unwrap();
    assert!(agg.prefix_hits >= 2, "pin hit replica 0, spill hit the migrated copy on replica 1");
    assert!(agg.prefix_hit_rate() > 0.0, "the fleet's aggregate hit rate must be positive");
    drop(h);
    router.shutdown();
}

#[test]
fn migration_accounting_is_exact_on_both_engines() {
    // export/adopt straight on two live engines, over an architecture
    // with per-layer KV geometry differences. The source's refcounts and
    // retained bytes must be exactly what they were before the export
    // (the clone borrows nothing), the destination must charge exactly
    // one segment and serve a byte-identical hit, and a second adopt of
    // the same path is refused without touching accounting.
    let be = backend();
    let mut rng = Rng::new(94);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store);
    let p: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]; // 12 tokens

    let mut a = replica_cfg().build(be.clone(), &store, &arch).unwrap();
    let id = a.submit(GenRequest::new(p.clone(), 6)).unwrap();
    let resp = a.run_to_completion().unwrap();
    let cold_tokens = resp.into_iter().find(|r| r.id == id).unwrap().tokens;
    assert_eq!(a.prefix_segments(), 1, "finish must retain the prompt+completion path");
    let a_alloc = a.kv_allocated_bytes();
    assert_eq!(a_alloc, a.prefix_retained_bytes(), "only the retained segment holds pages");
    assert!(a_alloc > 0);

    // export clones: the source's accounting must not move
    let export = a.export_prefix(&p).expect("the retained path must export");
    assert_eq!(export.seg.len, 8, "11 matchable tokens align down to 8 (page_len 4)");
    assert_eq!(export.tokens, &p[..8]);
    assert_eq!(export.prompt_tokens, 8, "the match ends inside the prompt part");
    assert_eq!(a.kv_allocated_bytes(), a_alloc, "export must not charge the source pool");
    assert_eq!(a.prefix_segments(), 1);

    // adopt charges exactly one segment on the destination
    let mut b = replica_cfg().build(be.clone(), &store, &arch).unwrap();
    assert!(b.adopt_prefix(export.clone()), "a compatible payload must be adopted");
    assert_eq!(b.prefix_segments(), 1);
    let b_alloc = b.kv_allocated_bytes();
    assert_eq!(b_alloc, b.prefix_retained_bytes());
    assert!(b_alloc > 0);

    // double-adopt of a covered path is refused, accounting untouched
    assert!(!b.adopt_prefix(export), "the path is already covered on B");
    assert_eq!((b.prefix_segments(), b.kv_allocated_bytes()), (1, b_alloc));

    // the adopted segment serves a byte-identical hit
    let id = b.submit(GenRequest::new(p.clone(), 6)).unwrap();
    let resp = b.run_to_completion().unwrap();
    let hit_tokens = resp.into_iter().find(|r| r.id == id).unwrap().tokens;
    assert_eq!(hit_tokens, cold_tokens, "the migrated hit must equal the cold run");
    assert_eq!(b.metrics.prefix_hits, 1);
    assert_eq!(b.metrics.prefix_tokens_saved, 8);

    // a local hit on the source is identical too
    let id = a.submit(GenRequest::new(p.clone(), 6)).unwrap();
    let resp = a.run_to_completion().unwrap();
    assert_eq!(resp.into_iter().find(|r| r.id == id).unwrap().tokens, cold_tokens);
    assert_eq!(a.metrics.prefix_hits, 1);

    // refcount exactness: both caches evict down to zero bytes. A leaked
    // reference from the export would pin the segment (evict_shared
    // refuses at refs > 0) and leave bytes behind.
    assert_eq!(a.clear_prefix_cache(), 1);
    assert_eq!((a.kv_allocated_bytes(), a.prefix_retained_bytes()), (0, 0));
    assert_eq!(b.clear_prefix_cache(), 1);
    assert_eq!((b.kv_allocated_bytes(), b.prefix_retained_bytes()), (0, 0));
}

#[test]
fn mid_migration_cancel_leaks_no_pages_on_either_replica() {
    // cancel the request whose placement triggered the migration, plus
    // the pin that forced it, then drain: both replicas must be down to
    // exactly their retained-segment bytes (nothing leaked), and the
    // destination keeps the migrated segment for the next hit.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(95);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let router = Router::spawn(
        build_engines(&be, &store, &arch, 2),
        RouterConfig { overload: 1, min_migrate: 1, ..RouterConfig::default() },
    );
    let h = router.handle();

    let shared: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];
    let with_tail = |tail: &[u32]| {
        let mut p = shared.clone();
        p.extend_from_slice(tail);
        p
    };
    let warm = h.submit(GenRequest::new(with_tail(&[20, 21, 22]), 6)).unwrap();
    let (_, warm_finish) = warm.collect();
    assert!(warm_finish.is_some());
    let pin = h.submit(GenRequest::new(with_tail(&[23, 24, 25]), 24)).unwrap();
    assert_eq!(pin.id() >> REPLICA_SHIFT, 0);

    let spill = h.submit(GenRequest::new(with_tail(&[26, 27, 28]), 12)).unwrap();
    assert_eq!(spill.id() >> REPLICA_SHIFT, 1, "the spill must route to the cold replica");
    assert_eq!(h.stats().unwrap().migrations, 1, "the spill's placement migrated the prefix");

    // tear both down mid-flight; the pin through the router-level cancel
    // (routed to replica 0 by the id's replica bits)
    spill.cancel();
    let (_, spill_finish) = spill.collect();
    assert!(spill_finish.is_some(), "the cancelled stream still gets its terminal item");
    h.cancel(pin.id());
    let (_, pin_finish) = pin.collect();
    assert!(pin_finish.is_some());

    wait_idle(&h);
    let stats = h.stats().unwrap();
    for (i, s) in stats.replicas.iter().enumerate() {
        assert_eq!(
            s.kv_allocated_bytes, s.prefix_retained_bytes,
            "replica {i}: every non-retained page must be back in the pool"
        );
    }
    assert!(stats.replicas[1].prefix_segments >= 1, "the migrated segment survives the cancel");
    drop(h);
    let engines = router.shutdown();
    for (i, e) in engines.iter().enumerate() {
        assert_eq!(e.kv_active_seqs(), 0, "replica {i}: no sequence may still hold pages");
        assert_eq!(e.kv_allocated_bytes(), e.prefix_retained_bytes());
    }
}

#[test]
fn fleet_tracing_observes_without_steering() {
    // the observability contract at fleet scope: turning on router +
    // replica tracers (one shared wall clock, the serve/bench wiring)
    // must not change a single generated token, and the router's ring
    // must actually have seen the placements it claims to observe.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(97);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let trace = TraceSpec::bursty(MixKind::Shared, 31).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);

    let untraced = {
        let router = Router::spawn(build_engines(&be, &store, &arch, 2), RouterConfig::default());
        let h = router.handle();
        let run = replay_wall(&trace, &h, Duration::from_millis(1), "untraced");
        drop(h);
        router.shutdown();
        transcript_of(&run.records)
    };

    let clock = Arc::new(Clock::wall());
    let engines: Vec<Engine> = (0..2)
        .map(|_| {
            replica_cfg()
                .tracer(Tracer::with_clock(clock.clone(), DEFAULT_RING_CAP))
                .build(be.clone(), &store, &arch)
                .unwrap()
        })
        .collect();
    let rcfg = RouterConfig {
        tracer: Tracer::with_clock(clock.clone(), DEFAULT_RING_CAP),
        ..RouterConfig::default()
    };
    let router = Router::spawn(engines, rcfg);
    let h = router.handle();
    let run = replay_wall(&trace, &h, Duration::from_millis(1), "traced");
    let fleet = h.trace_fleet().unwrap();
    let stats = h.stats().unwrap();
    drop(h);
    router.shutdown();

    assert_eq!(transcript_of(&run.records), untraced, "tracing must never steer sampling");
    assert_eq!(fleet.replicas.len(), 2);
    assert_eq!(fleet.dropped(), 0, "this workload fits the default ring");
    let routed = fleet
        .router
        .recs
        .iter()
        .filter(|r| matches!(r.ev, Event::Routed { .. }))
        .count() as u64;
    assert_eq!(routed, stats.total_routed(), "one Routed record per accepted request");
    let rounds = fleet
        .router
        .recs
        .iter()
        .filter(|r| matches!(r.ev, Event::ProbeRound { .. }))
        .count() as u64;
    assert_eq!(rounds, stats.probe_rounds, "one ProbeRound record per placement round");
    assert!(
        fleet.replicas.iter().all(|l| !l.recs.is_empty()),
        "every replica ring saw its share of the lifecycle"
    );
}

#[test]
fn migration_spans_pair_exactly_and_adopted_ends_match_stats() {
    // the warm/pin/spill scenario with the router's ring on: every
    // MigrationBegin must have its MigrationEnd (same ordinal, same
    // src/dst), and the ends that report an adopted segment must count
    // exactly what RouterStats.migrations counts.
    let be = backend();
    let mut rng = Rng::new(98);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let rcfg = RouterConfig {
        overload: 1,
        min_migrate: 1,
        tracer: Tracer::wall(DEFAULT_RING_CAP),
        ..RouterConfig::default()
    };
    let router = Router::spawn(build_engines(&be, &store, &arch, 2), rcfg);
    let h = router.handle();

    let shared: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];
    let with_tail = |tail: &[u32]| {
        let mut p = shared.clone();
        p.extend_from_slice(tail);
        p
    };
    let warm = h.submit(GenRequest::new(with_tail(&[20, 21, 22]), 6)).unwrap();
    assert!(warm.collect().1.is_some());
    let pin = h.submit(GenRequest::new(with_tail(&[23, 24, 25]), 24)).unwrap();
    assert_eq!(pin.id() >> REPLICA_SHIFT, 0);
    let spill = h.submit(GenRequest::new(with_tail(&[26, 27, 28]), 6)).unwrap();
    assert_eq!(spill.id() >> REPLICA_SHIFT, 1);
    assert!(spill.collect().1.is_some());
    assert!(pin.collect().1.is_some());

    let stats = h.stats().unwrap();
    let log = h.tracer().snapshot();
    drop(h);
    router.shutdown();

    assert_eq!(stats.migrations, 1);
    let begins: BTreeMap<u64, (usize, usize)> = log
        .recs
        .iter()
        .filter_map(|r| match r.ev {
            Event::MigrationBegin { mig, src, dst } => Some((mig, (src, dst))),
            _ => None,
        })
        .collect();
    let ends: Vec<(u64, usize, usize, bool)> = log
        .recs
        .iter()
        .filter_map(|r| match r.ev {
            Event::MigrationEnd { mig, src, dst, adopted, .. } => Some((mig, src, dst, adopted)),
            _ => None,
        })
        .collect();
    assert_eq!(begins.len(), ends.len(), "every migration begin must be closed");
    for (mig, src, dst, _) in &ends {
        assert_eq!(
            begins.get(mig),
            Some(&(*src, *dst)),
            "end {mig} must close a begin with the same src/dst"
        );
    }
    let adopted = ends.iter().filter(|(_, _, _, a)| *a).count() as u64;
    assert_eq!(adopted, stats.migrations, "adopted span ends ARE the migration counter");
    let tokens_moved: u64 = log
        .recs
        .iter()
        .filter_map(|r| match r.ev {
            Event::MigrationEnd { tokens, adopted: true, .. } => Some(tokens as u64),
            _ => None,
        })
        .sum();
    assert_eq!(tokens_moved, stats.migrated_tokens, "span payloads tally the token counter");
}

#[test]
fn digest_cached_probing_places_like_always_probing() {
    // satellite acceptance: with sequential submits (loads settled
    // between requests) the digest memo must produce byte-identical
    // placements to paying a channel probe every round — while actually
    // serving some probes from the cache.
    let be = backend();
    let mut rng = Rng::new(99);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let shared: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];
    let prompts: Vec<Vec<u32>> = vec![
        [shared.clone(), vec![20, 21, 22]].concat(),
        [shared.clone(), vec![23, 24, 25]].concat(),
        vec![2, 40, 41, 42, 43, 44, 45, 46],
        [shared.clone(), vec![26, 27, 28]].concat(),
        vec![2, 40, 41, 42, 43, 44, 45, 46], // exact repeat: memo-friendly
        [shared.clone(), vec![29, 30, 31]].concat(),
    ];

    let run = |probe_cache: bool| {
        let rcfg = RouterConfig { probe_cache, ..RouterConfig::default() };
        let router = Router::spawn(build_engines(&be, &store, &arch, 3), rcfg);
        let h = router.handle();
        let mut landings = Vec::new();
        let mut streams = BTreeMap::new();
        for p in &prompts {
            let s = h.submit(GenRequest::new(p.clone(), 6)).unwrap();
            landings.push((s.id() >> REPLICA_SHIFT) as usize);
            // full collect settles the replica's published load + digest
            // before the next placement decision
            let (tokens, finish) = s.collect();
            assert!(finish.is_some());
            streams.insert(landings.len(), tokens);
        }
        let stats = h.stats().unwrap();
        drop(h);
        router.shutdown();
        (landings, streams, stats)
    };

    let (paid_landings, paid_streams, paid) = run(false);
    let (memo_landings, memo_streams, memo) = run(true);
    assert_eq!(memo_landings, paid_landings, "the memo must never change a placement");
    assert_eq!(memo_streams, paid_streams, "or a token");
    assert_eq!(paid.digest_hits, 0, "probe_cache=false always pays the channel probe");
    assert_eq!(
        paid.digest_refreshes,
        (prompts.len() * 3) as u64,
        "every probe of every round goes over the channel"
    );
    assert!(memo.digest_hits > 0, "repeated prompts against idle replicas hit the memo");
    assert_eq!(
        memo.digest_hits + memo.digest_refreshes,
        (prompts.len() * 3) as u64,
        "every probe is either paid or served from the memo"
    );
    assert_eq!(memo.probe_rounds, prompts.len() as u64);
}

#[test]
fn open_loop_pacing_preserves_byte_identity() {
    // the bench-router regime: open-loop pacing changes when requests
    // arrive and how latency is billed, never what gets generated.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(96);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let trace = TraceSpec::bursty(MixKind::Shared, 29).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let want = sync_oracle(&be, &store, &arch, &trace);

    for pacing in [Pacing::Closed, Pacing::Open] {
        let server = AsyncServer::spawn(replica_cfg().build(be.clone(), &store, &arch).unwrap());
        let h = server.handle();
        let run = replay_wall_paced(&trace, &h, Duration::from_millis(1), "paced", pacing);
        drop(h);
        server.shutdown();
        assert_eq!(
            transcript_of(&run.records),
            want,
            "{pacing:?} pacing must generate the oracle's streams"
        );
        assert_eq!(run.intended, trace.requests());
    }
}
