//! Integration tests over the runtime `Backend`: block chaining, training
//! steps, BLD, and scoring, all running hermetically on the pure-Rust
//! `RefBackend` with the in-memory synthetic manifest — no `artifacts/`
//! directory, no `xla` crate, no python step.
//!
//! With the `pjrt` feature the same tests run against the AOT artifacts
//! through `XlaBackend` (requires `make artifacts`).

use puzzle::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use puzzle::bld;
use puzzle::data::{Batcher, CorpusMix, World};
use puzzle::gkd;
use puzzle::model::CompiledModel;
use puzzle::runtime::Backend;
use puzzle::train::{losses, train_step, Adam, AdamCfg, LossSpec};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;

#[cfg(not(feature = "pjrt"))]
fn backend() -> impl Backend {
    puzzle::runtime::RefBackend::tiny()
}

#[cfg(feature = "pjrt")]
fn backend() -> impl Backend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts/tiny missing — run `make artifacts` first"
    );
    puzzle::runtime::XlaBackend::open(&dir).expect("open artifact backend")
}

fn batcher(be: &dyn Backend, seed: u64) -> Batcher {
    let cfg = &be.man().cfg;
    let world = World::new(42, cfg.v as u32);
    Batcher::new(world, CorpusMix::distillation_mix(), cfg.b_train, cfg.s_train, seed)
}

#[test]
fn parent_forward_produces_finite_logits() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(1);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let model = CompiledModel::assemble(be.man(), &store, &arch).unwrap();
    let mut b = batcher(be, 7);
    let batch = b.next_batch();
    let trace = model.forward(be, "train", &batch.inputs, batch.b, batch.s).unwrap();
    let cfg = &be.man().cfg;
    assert_eq!(trace.logits.shape, vec![cfg.b_train, cfg.s_train, cfg.v]);
    assert!(trace.logits.data.iter().all(|x| x.is_finite()));
    // logits should not be constant
    let first = trace.logits.data[0];
    assert!(trace.logits.data.iter().any(|x| (x - first).abs() > 1e-6));
}

#[test]
fn heterogeneous_arch_assembles_and_runs() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(2);
    let mut store = init_parent(be.man(), &mut rng);
    let n = be.man().cfg.n_layers;
    // derive variants for layer 1 via the §3.2 inits
    for (kind, variant) in [("attn", "gqa_r2"), ("attn", "linear"), ("ffn", "r50"), ("ffn", "linear")] {
        let job = bld::Job { layer: 1, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: variant.into() };
        bld::init_job_weights(be.man(), &mut store, &job, None).unwrap();
    }
    let mut arch = Arch::parent(n);
    arch.layers[1] = (AttnChoice::Gqa { divisor: 2 }, FfnChoice::Ratio(3)); // gqa_r2 + r50
    arch.layers[n - 1] = (AttnChoice::NoOp, FfnChoice::NoOp);
    let model = CompiledModel::assemble(be.man(), &store, &arch).unwrap();
    let mut b = batcher(be, 8);
    let batch = b.next_batch();
    let trace = model.forward(be, "train", &batch.inputs, batch.b, batch.s).unwrap();
    assert!(trace.logits.data.iter().all(|x| x.is_finite()));
    // param count decreases vs parent
    let parent = CompiledModel::assemble(be.man(), &store, &Arch::parent(n)).unwrap();
    assert!(model.param_count(be.man()) < parent.param_count(be.man()));
}

#[test]
fn lm_training_reduces_loss() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(3);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let mut adam = Adam::new(AdamCfg { lr: 3e-3, ..Default::default() });
    let mut b = batcher(be, 9);
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..12 {
        let batch = b.next_batch();
        let m = train_step(be, &mut store, &arch, &mut adam, &batch, LossSpec::lm_only(), None, 3e-3)
            .unwrap();
        if step == 0 {
            first = m.lm;
        }
        last = m.lm;
    }
    assert!(
        last < first - 0.05,
        "LM loss should drop: first {first:.4} last {last:.4}"
    );
}

#[test]
fn bld_reduces_block_nmse_and_scoring_prefers_trained_blocks() {
    use puzzle::scoring::{self, Metric};

    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(4);
    let mut store = init_parent(be.man(), &mut rng);
    // brief parent pretrain so activations carry signal
    let mut b = batcher(be, 10);
    gkd::pretrain_parent(be, &mut store, &mut b, &[], 6, 3e-3).unwrap();

    // decoupled BLD on a reduced space
    let space = SearchSpace::reduced(
        vec![AttnChoice::Gqa { divisor: 1 }, AttnChoice::Gqa { divisor: 2 }, AttnChoice::NoOp],
        vec![FfnChoice::Ratio(0), FfnChoice::Ratio(3), FfnChoice::NoOp],
    );
    let report = bld::run_decoupled(be, &mut store, &space, &mut b, 8, 5e-3).unwrap();
    assert_eq!(report.jobs, be.man().cfg.n_layers * 2);
    for (k, v) in &report.final_loss {
        assert!(v.is_finite() && *v < 1.5, "job {k} nmse {v}");
    }

    // replace-1-block scores: trained gqa_r2 should beat noop on KL
    let val: Vec<_> = (0..2).map(|_| b.next_batch()).collect();
    let table = scoring::score_library(be, &store, &space, &val, Metric::Kl).unwrap();
    for l in 0..be.man().cfg.n_layers {
        let kl_gqa = table.get(l, "attn", "gqa_r2");
        let kl_noop = table.get(l, "attn", "noop");
        assert!(kl_gqa.is_finite() && kl_noop.is_finite());
        assert!(
            kl_gqa <= kl_noop + 1e-6,
            "layer {l}: trained gqa_r2 ({kl_gqa:.4}) should score no worse than noop ({kl_noop:.4})"
        );
    }
}

#[test]
fn gkd_kld_training_moves_child_toward_parent() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(5);
    let mut store = init_parent(be.man(), &mut rng);
    let mut b = batcher(be, 11);
    gkd::pretrain_parent(be, &mut store, &mut b, &[], 6, 3e-3).unwrap();

    // child: drop the last layer entirely; init remaining from parent
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[n - 1] = (AttnChoice::NoOp, FfnChoice::NoOp);

    let val: Vec<_> = (0..2).map(|_| b.next_batch()).collect();
    let cfg = gkd::GkdCfg { steps: 8, lr: 1e-3, spec: LossSpec::gkd_best(), ..Default::default() };
    // measure pre-GKD val KLD via a zero-step run
    let pre = gkd::run(be, &mut store.clone(), &arch, &mut batcher(be, 12), &val, &gkd::GkdCfg { steps: 1, lr: 0.0, ..cfg.clone() }).unwrap();
    let post = gkd::run(be, &mut store, &arch, &mut batcher(be, 12), &val, &cfg).unwrap();
    assert!(post.val_kld.is_finite() && pre.val_kld.is_finite());
    assert!(
        post.val_kld <= pre.val_kld + 0.02,
        "GKD should not increase KLD: pre {:.4} post {:.4}",
        pre.val_kld,
        post.val_kld
    );
}

#[test]
fn preload_and_stats_work_through_the_trait() {
    let be = backend();
    let be: &dyn Backend = &be;
    be.preload(&["embed_train", "head_train"]).unwrap();
    assert!(be.preload(&["no_such_exec"]).is_err(), "preloading an unknown exec must fail");
    // run something and check stats land in the snapshot
    let mut rng = Rng::new(6);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let model = CompiledModel::assemble(be.man(), &store, &arch).unwrap();
    let mut b = batcher(be, 13);
    let batch = b.next_batch();
    model.forward(be, "train", &batch.inputs, batch.b, batch.s).unwrap();
    assert!(be.measured_secs("embed_train").is_some());
    assert!(!be.stats_snapshot().is_empty());
}

#[test]
fn loss_parity_with_python_oracles() {
    // ce of uniform logits == ln(V)
    let v = 16;
    let logits = puzzle::tensor::Tensor::zeros(&[2, 3, v]);
    let targets = vec![0i32; 6];
    let (ce, _) = losses::ce_loss_and_grad(&logits, &targets);
    assert!((ce - (v as f64).ln()).abs() < 1e-6);
}

/// The v2 serving redesign's ownership contract: on the default build a
/// `SharedBackend` is an `Arc<dyn Backend + Send + Sync>`, so a backend
/// handle (and an engine holding one) can move to a server thread.
#[cfg(not(feature = "pjrt"))]
#[test]
fn shared_backend_handle_crosses_threads() {
    use puzzle::runtime::{share, SharedBackend};
    let be: SharedBackend = share(puzzle::runtime::RefBackend::tiny());
    let be2 = be.clone();
    let handle = std::thread::spawn(move || {
        let mut rng = Rng::new(21);
        let store = init_parent(be2.man(), &mut rng);
        let arch = Arch::parent(be2.man().cfg.n_layers);
        let model = CompiledModel::assemble(be2.man(), &store, &arch).unwrap();
        let cfg = &be2.man().cfg;
        let world = World::new(42, cfg.v as u32);
        let mut b = Batcher::new(world, CorpusMix::distillation_mix(), cfg.b_train, cfg.s_train, 3);
        let batch = b.next_batch();
        let trace = model.forward(&*be2, "train", &batch.inputs, batch.b, batch.s).unwrap();
        trace.logits.data.iter().all(|x| x.is_finite())
    });
    assert!(handle.join().unwrap(), "forward on a second thread must produce finite logits");
    // stats recorded on the worker thread are visible through the shared handle
    assert!(be.measured_secs("embed_train").is_some());
}
