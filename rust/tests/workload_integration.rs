//! Workload-harness integration tests: deterministic replay (satellite
//! of DESIGN.md §9 — same trace spec + seed reproduces the event log and
//! the BENCH json byte-for-byte, per engine configuration), multi-turn
//! prefix reuse over segments retained from *generated* tokens, the
//! cancel-during-chunked-prefill page-accounting regression, and
//! per-request gap bookkeeping. Hermetic (RefBackend + tiny manifest).

use puzzle::arch::Arch;
use puzzle::runtime::{share, SharedBackend};
use puzzle::serving::{EngineConfig, FinishReason, GenRequest};
use puzzle::specdec::{SpecBatch, SpecConfig};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;
use puzzle::weights::Store;
use puzzle::workload::{
    default_profiles, goodput, replay, report_json, MixKind, Server, Trace, TraceSpec, WorkloadRun,
};

#[cfg(not(feature = "pjrt"))]
fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

#[cfg(feature = "pjrt")]
fn backend() -> SharedBackend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    share(puzzle::runtime::XlaBackend::open(&dir).unwrap())
}

fn setup() -> (SharedBackend, Store, Arch, Trace) {
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(1);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let trace =
        TraceSpec::small(MixKind::MultiTurn, 7).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    (be, store, arch, trace)
}

fn engine_cfg(prefix: bool) -> EngineConfig {
    EngineConfig::new().kv_budget_bytes(16 << 20).page_len(4).prefix_cache(prefix, 8 << 20)
}

/// One fresh replay of `trace` under the named configuration.
fn run_once(
    be: &SharedBackend,
    store: &Store,
    arch: &Arch,
    trace: &Trace,
    config: &str,
) -> WorkloadRun {
    match config {
        "plain" => {
            let mut eng = engine_cfg(false).build(be.clone(), store, arch).unwrap();
            replay(trace, &mut Server::Engine(&mut eng), config).unwrap()
        }
        "prefix_cache" => {
            let mut eng = engine_cfg(true).build(be.clone(), store, arch).unwrap();
            replay(trace, &mut Server::Engine(&mut eng), config).unwrap()
        }
        "speculative" => {
            let cfg = SpecConfig { draft_k: 3, adapt_k_max: None, engine: engine_cfg(true) };
            let mut batch =
                SpecBatch::new(be.clone(), store, arch, store, arch, cfg).unwrap();
            replay(trace, &mut Server::Spec(&mut batch), config).unwrap()
        }
        other => panic!("unknown test config {other}"),
    }
}

#[test]
fn replay_is_deterministic_per_engine_configuration() {
    let (be, store, arch, trace) = setup();
    let slos = default_profiles();
    for config in ["plain", "prefix_cache", "speculative"] {
        let a = run_once(&be, &store, &arch, &trace, config);
        let b = run_once(&be, &store, &arch, &trace, config);
        assert!(!a.event_log.is_empty(), "{config}: replay must log events");
        assert_eq!(a.event_log, b.event_log, "{config}: event log must be byte-identical");
        assert_eq!(a.ticks, b.ticks, "{config}: virtual tick count must agree");
        // the BENCH json (which excludes wall clock) must also agree
        // byte-for-byte — the property the CI artifact diff relies on
        let ja = report_json(&trace, &[a], &slos).to_pretty();
        let jb = report_json(&trace, &[b], &slos).to_pretty();
        assert_eq!(ja, jb, "{config}: BENCH_workloads.json must be reproducible");
    }
}

#[test]
fn multiturn_replay_hits_segments_retained_from_generated_tokens() {
    let (be, store, arch, trace) = setup();
    let plain = run_once(&be, &store, &arch, &trace, "plain");
    let warm = run_once(&be, &store, &arch, &trace, "prefix_cache");
    // later turns land on segments retained at earlier turns' *finish*,
    // which cover the completion tokens — the PR's engine change
    assert!(warm.metrics.prefix_hits > 0, "multi-turn prompts must hit the cache");
    assert!(
        warm.metrics.prefix_gen_hits > 0,
        "hits must extend past the prompt into generated-origin rows"
    );
    assert!(warm.metrics.prefix_gen_tokens_saved > 0);
    // caching is an optimization, not a model change: every request's
    // token stream matches the plain engine's byte-for-byte
    assert_eq!(plain.records.len(), warm.records.len());
    for (p, w) in plain.records.iter().zip(&warm.records) {
        assert_eq!((p.conv, p.turn), (w.conv, w.turn));
        assert_eq!(p.gen, w.gen, "conv {} turn {}: cached generation diverged", p.conv, p.turn);
        assert_eq!(p.finish, w.finish);
    }
    // structural SLO sanity on real runs: strict is componentwise tighter
    let [lenient, strict] = default_profiles();
    for run in [&plain, &warm] {
        assert!(goodput(run, &strict).1 <= goodput(run, &lenient).1 + 1e-12);
    }
}

#[test]
fn cancel_during_chunked_prefill_frees_pages_and_retains_no_partial_segment() {
    let (be, store, arch, _) = setup();
    let cfg = be.man().cfg.clone();
    let mut eng = engine_cfg(true).build(be.clone(), &store, &arch).unwrap();
    // prompt longer than the prefill window: admit ingests one
    // s_prefill-sized chunk (retained — it was fully ingested), then
    // teacher-forces the tail one token per step
    let plen = cfg.s_prefill + 8;
    let prompt: Vec<u32> = (0..plen).map(|i| (i % (cfg.v - 2)) as u32 + 1).collect();
    let id = eng.submit(GenRequest::new(prompt.clone(), 8)).unwrap();
    eng.step().unwrap(); // admit + first teacher-forced tail token
    assert_eq!(eng.active(), 1);
    assert_eq!(eng.metrics.chunked_prefills, 1);
    assert_eq!(eng.prefix_segments(), 1, "the ingested first chunk is retained at admit");
    let retained = eng.prefix_retained_bytes();
    assert!(retained > 0);

    // cancel while the unmatched suffix is still being teacher-forced
    assert!(eng.cancel(id));
    assert_eq!(eng.active(), 0);
    assert_eq!(
        eng.kv_allocated_bytes(),
        eng.prefix_retained_bytes(),
        "cancel must free the sequence's pages exactly (only retained segment bytes remain)"
    );
    assert_eq!(eng.prefix_retained_bytes(), retained);
    assert_eq!(
        eng.prefix_segments(),
        1,
        "a partially teacher-forced prompt must not become a new segment"
    );
    assert_eq!(eng.metrics.prefix_gen_hits, 0);
    let resp = eng.take_finished().pop().expect("cancelled response is emitted");
    assert_eq!(resp.finish, FinishReason::Cancelled);

    // the same prompt resubmitted hits exactly the admit-time chunk — if
    // cancel had retained teacher-forced progress, more would be saved
    eng.submit(GenRequest::new(prompt, 4)).unwrap();
    eng.run_to_completion().unwrap();
    assert_eq!(eng.metrics.prefix_hits, 1);
    assert_eq!(eng.metrics.prefix_tokens_saved, cfg.s_prefill);
}

#[test]
fn per_request_gap_counts_match_token_streams() {
    let (be, store, arch, trace) = setup();
    let run = run_once(&be, &store, &arch, &trace, "prefix_cache");
    assert!(run.completed() > 0);
    for r in &run.records {
        match r.finish {
            Some(_) => {
                assert!(!r.gen.is_empty(), "finished requests emit at least one token");
                assert_eq!(r.gaps.len() + 1, r.gen.len(), "one gap per token after the first");
                let ttft = r.ttft_ticks().expect("finished requests have a first token");
                assert!(r.e2e_ticks() >= ttft);
            }
            None => assert!(r.gen.is_empty(), "rejected requests never emit tokens"),
        }
    }
    // the engine-side ITL series is one gap per decode-emitted token
    // after each sequence's first — it must be populated on a real run
    assert!(!run.metrics.itl.is_empty());
}
