//! Integration tests for the threaded async front-end (`server`):
//! concurrent clients streaming byte-identical results, cancellation
//! mid-chunked-prefill with exact page accounting, queue-full shedding,
//! dropped-stream auto-cancel, shutdown, and wall-clock trace replay
//! matching the virtual-tick driver byte for byte. The server needs
//! `Engine: Send`, so this whole crate is compiled only on the default
//! (non-pjrt) backend build.
#![cfg(not(feature = "pjrt"))]

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{share, Backend, SharedBackend};
use puzzle::server::{AsyncServer, StreamItem};
use puzzle::serving::{EngineConfig, FinishReason, GenRequest, SamplingParams};
use puzzle::util::Rng;
use puzzle::weights::store::{block_key, init_parent};
use puzzle::weights::Store;

fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

fn variable_arch(be: &dyn Backend, store: &mut Store) -> Arch {
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind, variant: v };
                bld::init_job_weights(be.man(), store, &job, None).unwrap();
            }
        }
    }
    arch
}

/// Zero every residual block and craft the embedding so the model
/// deterministically self-loops on token `y` (see serving_integration).
fn self_loop_store(be: &dyn Backend, y: u32, rng: &mut Rng) -> Store {
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut store = init_parent(be.man(), rng);
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = (y as usize) * d;
    e.data[row..row + d].fill(0.0);
    e.data[row] = 1.0;
    store.put("embed", e);
    store
}

#[test]
fn concurrent_clients_stream_byte_identical_results() {
    // 8 client threads hammer one worker-owned engine running budgeted
    // chunked prefill; every stream must be byte-identical to a
    // synchronous engine with inline prefills — greedy and seeded
    // stochastic sampling, over a variable-KV-head child architecture.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(81);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store);
    let world = World::new(2, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(3);
    let n_req = 16usize;
    let clients = 8usize;
    let reqs: Vec<GenRequest> = (0..n_req)
        .map(|i| {
            let plen = prng.range(4, cfg.s_prefill.min(32));
            let prompt = sample_sequence(&world, &mix, plen, &mut prng);
            let sampling = if i % 2 == 0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::temperature(0.8).with_seed(60 + i as u64)
            };
            GenRequest::new(prompt, 6).with_sampling(sampling)
        })
        .collect();

    // sync oracle: no budget, inline prefills
    let mut sync_eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let ids: Vec<u64> = reqs.iter().map(|r| sync_eng.submit(r.clone()).unwrap()).collect();
    let resp = sync_eng.run_to_completion().unwrap();
    let oracle: Vec<Vec<u32>> = ids
        .iter()
        .map(|id| resp.iter().find(|r| r.id == *id).unwrap().tokens.clone())
        .collect();

    let eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefill_budget(5)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let server = AsyncServer::spawn(eng);
    let mut got: Vec<(usize, Vec<u32>, Option<FinishReason>)> = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..clients)
            .map(|ci| {
                let h = server.handle();
                let lot: Vec<(usize, GenRequest)> = reqs
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % clients == ci)
                    .map(|(i, r)| (i, r.clone()))
                    .collect();
                s.spawn(move || {
                    lot.into_iter()
                        .map(|(i, req)| {
                            let (tokens, finish) = h.submit(req).unwrap().collect();
                            (i, tokens, finish)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for j in joins {
            got.extend(j.join().unwrap());
        }
    });
    assert_eq!(got.len(), n_req);
    for (i, tokens, finish) in &got {
        assert!(finish.is_some(), "request {i} must finish");
        assert_eq!(tokens, &oracle[*i], "async chunked stream {i} must match the sync engine");
    }
    let eng = server.shutdown();
    assert!(eng.metrics.prefill_chunk_passes > 0, "the budget must have driven chunk passes");
    assert_eq!(eng.metrics.prefills, 0, "a budgeted engine never runs inline prefills");
    assert_eq!(eng.metrics.requests_completed, n_req);
}

#[test]
fn cancel_mid_chunked_prefill_frees_pages_and_streams_cancelled() {
    // the cancellation satellite: a huge prompt is cancelled while its
    // chunked ingestion is still in flight, THROUGH the async handle.
    // Its stream must end with Finished(Cancelled) and zero tokens, its
    // pages must come back exactly, and no partial prefix segment may be
    // retained — all while a live lane keeps decoding undisturbed.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let y = 10u32;
    let mut rng = Rng::new(82);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefill_budget(2) // tiny budget: the monster needs ~20 steps to ingest
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let server = AsyncServer::spawn(eng);
    let h = server.handle();

    // a live lane that keeps the worker stepping (self-loop on y); its
    // generous budget keeps it alive across the cancel + stats round-trip
    let live = h.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    assert!(
        matches!(live.recv(), Some(StreamItem::Token(t)) if t == y),
        "live lane must be decoding before the monster arrives"
    );
    let before = h.stats().unwrap();
    assert!(before.kv_allocated_bytes > 0);

    // monster prompt: 43 pending tokens at budget 2 — its first sampled
    // token is ~20 steps away, so the cancel lands mid-ingestion
    let monster: Vec<u32> = std::iter::once(1u32)
        .chain(std::iter::repeat(y))
        .take(cfg.s_max - 4)
        .collect();
    let stream = h.submit(GenRequest::new(monster, 2)).unwrap();
    stream.cancel();
    let (tokens, finish) = stream.collect();
    assert_eq!(finish, Some(FinishReason::Cancelled), "the stream must see the cancel");
    assert!(tokens.is_empty(), "cancelled mid-prefill: no token was ever sampled");

    // exact page accounting: the monster's full-horizon booking is gone,
    // the live lane's pages are untouched (horizons are booked at admit,
    // so per-sequence bytes are constant while it runs)
    let after = h.stats().unwrap();
    assert_eq!(
        after.kv_allocated_bytes, before.kv_allocated_bytes,
        "cancel must free exactly the monster's pages"
    );
    assert_eq!(after.prefix_segments, 0, "no partial-prefix segment may be retained");
    assert_eq!(after.active, 1, "the live lane survives the cancel");

    // the live lane finishes undisturbed (its first token was consumed
    // above; collect drains the rest of its 40-token budget)
    let (live_tokens, live_finish) = live.collect();
    assert_eq!(live_tokens, vec![y; 39]);
    assert_eq!(live_finish, Some(FinishReason::MaxNew));

    let eng = server.shutdown();
    assert_eq!(eng.metrics.cancelled, 1);
    assert!(eng.metrics.prefill_chunk_tokens > 0, "ingestion had started when the cancel hit");
}

#[test]
fn queue_full_shedding_rejects_only_the_overflow_client() {
    // graceful shedding: with both lanes busy and a 1-deep queue, the
    // fourth submit comes back as an Err on ITS client only; everything
    // already accepted still completes, and a freed lane admits the
    // queued request.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(83);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .max_queue(1)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let server = AsyncServer::spawn(eng);
    let h = server.handle();

    // fill both decode lanes (wait for a token = admission happened)
    let a = h.submit(GenRequest::new(vec![1, y], 30)).unwrap();
    assert!(matches!(a.recv(), Some(StreamItem::Token(_))));
    let b = h.submit(GenRequest::new(vec![2, y], 12)).unwrap();
    assert!(matches!(b.recv(), Some(StreamItem::Token(_))));
    // c waits in the queue (no lane free: both self-loop mid-generation)
    let c = h.submit(GenRequest::new(vec![3, y], 4)).unwrap();
    // d overflows the 1-deep queue: shed with the engine's message
    let err = match h.submit(GenRequest::new(vec![4, y], 4)) {
        Err(e) => e,
        Ok(_) => panic!("the fourth submit must be shed by the full queue"),
    };
    assert!(err.to_string().contains("queue"), "shed cause must surface to the client: {err}");

    // cancelling a frees its lane; c gets admitted and completes
    a.cancel();
    let (_, a_finish) = a.collect();
    assert_eq!(a_finish, Some(FinishReason::Cancelled));
    let (c_tokens, c_finish) = c.collect();
    assert_eq!(c_tokens, vec![y; 4], "the queued request must run once a lane frees");
    assert_eq!(c_finish, Some(FinishReason::MaxNew));
    // b's first token was consumed above; collect drains the other 11
    let (b_tokens, b_finish) = b.collect();
    assert_eq!(b_tokens, vec![y; 11]);
    assert_eq!(b_finish, Some(FinishReason::MaxNew));

    let stats = h.stats().unwrap();
    assert_eq!((stats.active, stats.queued, stats.kv_allocated_bytes), (0, 0, 0));
    let eng = server.shutdown();
    assert_eq!(eng.metrics.requests_completed, 2);
    assert_eq!(eng.metrics.cancelled, 1);
    assert_eq!(eng.metrics.rejected_prompts, 1);
}

#[test]
fn dropped_stream_auto_cancels_its_request() {
    // an abandoned client must not pin a decode lane: once its stream is
    // dropped, the next token send fails and the worker cancels the
    // request, freeing the lane and its pages.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(84);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let server = AsyncServer::spawn(eng);
    let h = server.handle();

    let s = h.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    assert!(matches!(s.recv(), Some(StreamItem::Token(_))));
    drop(s); // client walks away mid-generation

    // the worker notices on its next token send; poll until the lane is
    // back (bounded: the engine emits one token per step)
    let mut freed = false;
    for _ in 0..200 {
        let st = h.stats().unwrap();
        if st.active == 0 && st.kv_allocated_bytes == 0 {
            freed = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(freed, "dropping the stream must cancel the request and free its lane");
    let eng = server.shutdown();
    assert_eq!(eng.metrics.cancelled, 1);
    assert!(
        eng.metrics.generated_tokens < 40,
        "the auto-cancel must land well before the request's budget"
    );
}

#[test]
fn wall_replay_matches_virtual_replay_byte_for_byte() {
    // the bench-async invariant in test form: one trace, replayed on the
    // virtual tick clock (sync) and in wall-clock time through the async
    // server — unchunked AND chunked — must generate identical streams
    // for every (conversation, turn).
    use std::collections::BTreeMap;
    use std::time::Duration;

    use puzzle::workload::{replay, replay_wall, MixKind, Server, TraceSpec};

    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(85);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut spec = TraceSpec::small(MixKind::Mixed, 11);
    spec.conversations = 4;
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let engine_cfg = || EngineConfig::new().kv_budget_bytes(16 << 20).page_len(4).max_queue(1024);

    let oracle = {
        let mut eng = engine_cfg().build(be.clone(), &store, &arch).unwrap();
        replay(&trace, &mut Server::Engine(&mut eng), "sync").unwrap()
    };
    let want: BTreeMap<(usize, usize), Vec<u32>> =
        oracle.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect();

    for budget in [None, Some(6)] {
        let mut ec = engine_cfg();
        if let Some(b) = budget {
            ec = ec.prefill_budget(b);
        }
        let server = AsyncServer::spawn(ec.build(be.clone(), &store, &arch).unwrap());
        let h = server.handle();
        let run = replay_wall(&trace, &h, Duration::from_millis(1), "wall");
        drop(h);
        let eng = server.shutdown();
        let got: BTreeMap<(usize, usize), Vec<u32>> =
            run.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect();
        assert_eq!(got, want, "wall replay (budget {budget:?}) must match the tick replay");
        assert_eq!(run.intended, trace.requests());
        if budget.is_some() {
            assert!(eng.metrics.prefill_chunk_passes > 0, "chunked run must spend its budget");
        }
    }
}
