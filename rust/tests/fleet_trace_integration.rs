//! End-to-end fleet tracing (`DESIGN.md §13`): a live 4-replica router
//! with router + replica rings over one shared clock must merge into a
//! single Chrome-trace timeline whose stitched per-request tracks tile
//! their lifecycle exactly; identical virtual-clock replays must produce
//! identical rings (modulo the worker's control-triggered idle steps,
//! whose count is scheduling-dependent by design); and the router's
//! Prometheus scrape must carry the ring-loss counter and the live SLO
//! burn-rate gauges folded from those rings. `Engine: Send` is required,
//! so this crate compiles only on the default (non-pjrt) backend build.
#![cfg(not(feature = "pjrt"))]

use std::sync::Arc;

use puzzle::arch::Arch;
use puzzle::obs::{
    fleet_jsonl, merge_fleet, scrape_value, Clock, Event, FleetLog, Rec, TraceLog, Tracer,
    DEFAULT_RING_CAP,
};
use puzzle::runtime::{share, SharedBackend};
use puzzle::server::{Router, RouterConfig, RouterHandle, REPLICA_SHIFT};
use puzzle::serving::{Engine, EngineConfig, GenRequest};
use puzzle::util::{Json, Rng};
use puzzle::weights::store::init_parent;
use puzzle::weights::Store;

/// Matches the (private) exporter constant: per-request tracks start here.
const TID_REQ_BASE: u64 = 1_000;

fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

fn replica_cfg() -> EngineConfig {
    EngineConfig::new()
        .kv_budget_bytes(16 << 20)
        .page_len(4)
        .max_queue(1024)
        .prefix_cache(true, 8 << 20)
}

/// A router over `n` replicas whose every ring shares `clock`.
fn traced_fleet(
    be: &SharedBackend,
    store: &Store,
    arch: &Arch,
    n: usize,
    clock: &Arc<Clock>,
) -> Router {
    let engines: Vec<Engine> = (0..n)
        .map(|_| {
            replica_cfg()
                .tracer(Tracer::with_clock(clock.clone(), DEFAULT_RING_CAP))
                .build(be.clone(), store, arch)
                .unwrap()
        })
        .collect();
    let rcfg = RouterConfig {
        tracer: Tracer::with_clock(clock.clone(), DEFAULT_RING_CAP),
        ..RouterConfig::default()
    };
    Router::spawn(engines, rcfg)
}

fn snapshot_fleet(h: &RouterHandle) -> FleetLog {
    h.trace_fleet().unwrap()
}

#[test]
fn four_replica_merged_trace_stitches_and_tiles_exactly() {
    // the acceptance artifact, produced live: 4 traced replicas behind a
    // traced router on one wall clock, a concurrent burst of requests,
    // one merged timeline. Every routed request must appear as a pid-0
    // track whose placement + queued + prefill + decode children tile
    // the enclosing span to the microsecond, stitched to a request span
    // on the owning replica's own pid by the global id.
    let be = backend();
    let mut rng = Rng::new(101);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let clock = Arc::new(Clock::wall());
    let router = traced_fleet(&be, &store, &arch, 4, &clock);
    let h = router.handle();

    // a concurrent burst so placement has in-flight depth to spread on
    let streams: Vec<_> = (0..8u32)
        .map(|i| {
            h.submit(GenRequest::new(vec![1, 2 + i, 3 + i, 4 + i, 5 + i, 6 + i], 8)).unwrap()
        })
        .collect();
    let n_requests = streams.len();
    for s in streams {
        let (_, finish) = s.collect();
        assert!(finish.is_some(), "every request must reach a terminal item");
    }

    let fleet = snapshot_fleet(&h);
    let stats = h.stats().unwrap();
    drop(h);
    router.shutdown();

    assert_eq!(stats.total_routed(), n_requests as u64);
    assert_eq!(fleet.replicas.len(), 4);
    assert_eq!(fleet.dropped(), 0, "the burst fits the default rings");

    let doc = merge_fleet(&fleet);
    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let pname = |pid: f64| {
        evs.iter()
            .find(|e| {
                e.get("name").unwrap().as_str() == Some("process_name")
                    && e.get("pid").unwrap().as_f64() == Some(pid)
            })
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
    };
    assert_eq!(pname(0.0).as_deref(), Some("puzzle-router"));
    for r in 0..4 {
        assert_eq!(pname((r + 1) as f64).as_deref(), Some(&*format!("puzzle-replica-{r}")));
    }

    // one routed instant per request, all on the router's routing track
    let routed: Vec<&Json> =
        evs.iter().filter(|e| e.get("name").unwrap().as_str() == Some("routed")).collect();
    assert_eq!(routed.len(), n_requests);
    for e in &routed {
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(0.0));
        assert_eq!(e.get("tid").unwrap().as_f64(), Some(0.0));
    }

    // every stitched pid-0 request track tiles exactly and resolves to a
    // request span on its replica's pid
    let pid0_reqs: Vec<&Json> = evs
        .iter()
        .filter(|e| {
            e.get("pid").unwrap().as_f64() == Some(0.0)
                && e.get("name").unwrap().as_str() == Some("request")
        })
        .collect();
    assert_eq!(pid0_reqs.len(), n_requests, "every routed request gets a fleet track");
    for req in pid0_reqs {
        let tid = req.get("tid").unwrap().as_f64().unwrap();
        let (r0, rdur) =
            (req.get("ts").unwrap().as_f64().unwrap(), req.get("dur").unwrap().as_f64().unwrap());
        let args = req.get("args").unwrap();
        let gid = args.get("id").unwrap().as_f64().unwrap() as u64;
        let rep = args.get("replica").unwrap().as_f64().unwrap() as u64;
        assert_eq!(gid >> REPLICA_SHIFT, rep, "the global id encodes its replica");
        assert_eq!(tid, (TID_REQ_BASE + gid) as f64);
        let mut cursor = r0;
        for stage in ["placement", "queued", "prefill", "decode"] {
            let s = evs
                .iter()
                .find(|e| {
                    e.get("pid").unwrap().as_f64() == Some(0.0)
                        && e.get("tid").unwrap().as_f64() == Some(tid)
                        && e.get("name").unwrap().as_str() == Some(stage)
                })
                .unwrap_or_else(|| panic!("request {gid} lacks its {stage} span"));
            assert_eq!(s.get("ts").unwrap().as_f64(), Some(cursor), "{stage} must start flush");
            cursor += s.get("dur").unwrap().as_f64().unwrap();
        }
        assert_eq!(cursor, r0 + rdur, "the four stages must tile e2e exactly");
        // cross-pid stitch: the owning replica carries the same id
        assert!(
            evs.iter().any(|e| e.get("pid").unwrap().as_f64() == Some((rep + 1) as f64)
                && e.get("tid").unwrap().as_f64() == Some((TID_REQ_BASE + gid) as f64)
                && e.get("name").unwrap().as_str() == Some("request")),
            "request {gid} has no replica-side track on pid {}",
            rep + 1
        );
    }
}

/// Drop the control-triggered `step` records whose *count* (not content)
/// depends on how the worker's message batches land relative to its idle
/// steps — the one scheduling artifact in an otherwise deterministic ring.
fn without_steps(log: &TraceLog) -> TraceLog {
    TraceLog {
        recs: log
            .recs
            .iter()
            .filter(|r| !matches!(r.ev, Event::Step { .. }))
            .cloned()
            .collect(),
        dropped: log.dropped,
    }
}

#[test]
fn virtual_clock_fleet_rings_replay_byte_identically() {
    // the determinism contract behind the CI fleet gate: two identical
    // sequential replays on the shared virtual clock produce the same
    // router ring record-for-record and the same replica lifecycles, so
    // the merged JSONL is byte-identical.
    let be = backend();
    let mut rng = Rng::new(102);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let shared: Vec<u32> = vec![1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];
    let prompts: Vec<Vec<u32>> = vec![
        [shared.clone(), vec![20, 21, 22]].concat(),
        [shared.clone(), vec![23, 24, 25]].concat(),
        vec![2, 40, 41, 42, 43, 44, 45, 46],
        [shared.clone(), vec![26, 27, 28]].concat(),
    ];

    let run = || {
        let clock = Arc::new(Clock::virtual_ticks());
        let router = traced_fleet(&be, &store, &arch, 2, &clock);
        let h = router.handle();
        for (k, p) in prompts.iter().enumerate() {
            // one tick per request phase; the full collect settles the
            // fleet before the clock moves, so every record of phase k
            // is stamped k on whichever thread wrote it
            clock.set_tick(k as u64);
            let s = h.submit(GenRequest::new(p.clone(), 6)).unwrap();
            assert!(s.collect().1.is_some());
        }
        let fleet = snapshot_fleet(&h);
        drop(h);
        router.shutdown();
        fleet
    };

    let (a, b) = (run(), run());
    assert_eq!(a.router.recs, b.router.recs, "router rings must replay byte-identically");
    assert_eq!(a.replicas.len(), b.replicas.len());
    for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
        assert_eq!(
            without_steps(ra).recs,
            without_steps(rb).recs,
            "replica lifecycles must replay byte-identically"
        );
    }
    let strip = |f: &FleetLog| FleetLog {
        router: f.router.clone(),
        replicas: f.replicas.iter().map(without_steps).collect(),
    };
    assert_eq!(
        fleet_jsonl(&strip(&a)),
        fleet_jsonl(&strip(&b)),
        "the merged fleet JSONL must be byte-stable across replays"
    );

    // the router ring really carries the fleet grammar
    let routed: Vec<&Rec> =
        a.router.recs.iter().filter(|r| matches!(r.ev, Event::Routed { .. })).collect();
    assert_eq!(routed.len(), prompts.len());
    for (k, r) in routed.iter().enumerate() {
        assert_eq!(r.ts_us, (k as u64) * puzzle::obs::TICK_US, "routed at its phase tick");
    }
}

#[test]
fn fleet_scrape_exposes_ring_loss_and_burn_gauges() {
    // the live monitor: a traced fleet's scrape must carry the ring-loss
    // counter and, folded from the rings at scrape time, per-profile
    // windowed goodput and burn-rate gauges with the finished requests
    // in-window.
    let be = backend();
    let mut rng = Rng::new(103);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let clock = Arc::new(Clock::virtual_ticks());
    let router = traced_fleet(&be, &store, &arch, 2, &clock);
    let h = router.handle();

    // ticks start at 1: the scrape window predicate is half-open
    // (`finish_us > now - window`), so with `now` inside the first window
    // the lower bound saturates to 0 and a request finishing at tick 0
    // would fall on the excluded boundary
    for k in 1..=3u64 {
        clock.set_tick(k);
        let s = h.submit(GenRequest::new(vec![1, 10 + k as u32, 11, 12, 13, 14], 6)).unwrap();
        assert!(s.collect().1.is_some());
    }

    let text = h.metrics_text().unwrap();
    drop(h);
    router.shutdown();

    assert_eq!(
        scrape_value(&text, "puzzle_trace_dropped_events"),
        Some(0.0),
        "ring loss must be scrapable (and zero here)"
    );
    assert_eq!(
        scrape_value(&text, "puzzle_slo_window_requests_1m"),
        Some(3.0),
        "all three finishes land inside the short window"
    );
    for profile in ["lenient", "strict"] {
        for window in ["1m", "5m"] {
            let goodput = scrape_value(&text, &format!("puzzle_slo_{profile}_goodput_{window}"))
                .unwrap_or_else(|| panic!("missing {profile}/{window} goodput gauge"));
            assert!((0.0..=1.0).contains(&goodput));
            let burn = scrape_value(&text, &format!("puzzle_slo_{profile}_burn_rate_{window}"))
                .unwrap_or_else(|| panic!("missing {profile}/{window} burn gauge"));
            assert!(burn >= 0.0);
        }
    }
    // same-tick submit/first-token/finish: TTFT and every gap are 0 µs,
    // so even the strict profile is met and nothing burns
    assert_eq!(scrape_value(&text, "puzzle_slo_strict_goodput_1m"), Some(1.0));
    assert_eq!(scrape_value(&text, "puzzle_slo_strict_burn_rate_1m"), Some(0.0));
    assert_eq!(
        scrape_value(&text, "puzzle_router_probe_rounds_total"),
        Some(3.0),
        "one placement round per request"
    );
}
