//! Speculative-decoding integration tests: the correctness invariant
//! (greedy speculative output is byte-identical to plain greedy parent
//! decoding — any draft length, any drafter, chunked prompts included,
//! batched or not), the fused-verify ≡ sequential-decode logits
//! equivalence, exact KV rollback at both the engine and the
//! page-accounting level (including one lane rolling back while others
//! advance), seeded reproducibility of stochastic speculation, and the
//! analytic speedup model validated against a measured run. Hermetic:
//! RefBackend over the in-memory synthetic manifest.

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::world::EOS;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{share, Backend, SharedBackend};
use puzzle::serving::{EngineConfig, FinishReason, GenRequest, SamplingParams, SpecFeed};
use puzzle::specdec::{
    expected_tokens_per_pass, SpecBatch, SpecConfig, SpecRequest, SpecSession,
};
use puzzle::util::Rng;
use puzzle::weights::store::{block_key, init_parent};
use puzzle::weights::Store;

#[cfg(not(feature = "pjrt"))]
fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

#[cfg(feature = "pjrt")]
fn backend() -> SharedBackend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    share(puzzle::runtime::XlaBackend::open(&dir).unwrap())
}

/// A Puzzle-style child: cheaper attention on two layers, a slimmer FFN
/// on one, weights training-free-initialized from the parent (bld §3.2).
fn child_arch(be: &dyn Backend, store: &mut Store) -> Arch {
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: v };
                bld::init_job_weights(be.man(), store, &job, None).unwrap();
            }
        }
    }
    arch
}

/// Zero every residual block and craft the embedding so greedy decoding
/// self-loops on token `y` forever (never EOS) — deterministic long
/// generations for exact-count assertions.
fn self_loop_store(be: &dyn Backend, y: u32, rng: &mut Rng) -> Store {
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut store = init_parent(be.man(), rng);
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = (y as usize) * d;
    e.data[row..row + d].fill(0.0);
    e.data[row] = 1.0;
    store.put("embed", e);
    store
}

/// Plain greedy decoding through the batched engine: the oracle.
fn plain_greedy(be: &SharedBackend, store: &Store, arch: &Arch, prompts: &[Vec<u32>], max_new: usize) -> Vec<Vec<u32>> {
    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), store, arch).unwrap();
    let mut ids = Vec::new();
    for p in prompts {
        ids.push(eng.submit(GenRequest::new(p.clone(), max_new)).unwrap());
    }
    let resp = eng.run_to_completion().unwrap();
    ids.iter()
        .map(|id| resp.iter().find(|r| r.id == *id).unwrap().tokens.clone())
        .collect()
}

#[test]
fn greedy_speculative_is_byte_identical_to_plain_decoding() {
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(31);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();

    let mut prompts: Vec<Vec<u32>> = Vec::new();
    let mut prng = Rng::new(8);
    for len in [4usize, 7, 12, 20] {
        prompts.push(sample_sequence(&world, &mix, len, &mut prng));
    }
    // one prompt past the prefill window: the chunked spec_open path
    prompts.push(sample_sequence(&world, &mix, cfg.s_prefill, &mut prng));
    assert!(prompts.last().unwrap().len() > cfg.s_prefill);

    let max_new = 8usize;
    let oracle = plain_greedy(&be, &store, &parent, &prompts, max_new);
    assert!(oracle.iter().any(|t| t.len() > 1), "oracle generations must be non-trivial");

    // the invariant must hold for ANY drafter and ANY draft length: the
    // drafts only ever gate wall-clock, never content
    for (name, drafter_arch) in [("self", &parent), ("puzzle_child", &child)] {
        for draft_k in [1usize, 3, 6] {
            let mut sess = SpecSession::new(
                be.clone(),
                &store,
                &parent,
                &store,
                drafter_arch,
                SpecConfig { draft_k, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
            )
            .unwrap();
            for (p, want) in prompts.iter().zip(&oracle) {
                let r = sess.generate(p, max_new, SamplingParams::greedy()).unwrap();
                assert_eq!(
                    &r.tokens, want,
                    "drafter {name}, k={draft_k}: speculative greedy must match plain greedy"
                );
                assert!(matches!(r.finish, FinishReason::Eos | FinishReason::MaxNew));
                // exact rollback: no pages may survive the request
                assert_eq!(sess.kv_allocated_bytes(), (0, 0), "KV pages leaked");
            }
        }
    }
}

#[test]
fn horizon_reaching_prompts_stay_byte_identical() {
    // max_new larger than the cache allows: plain decoding finishes
    // CacheHorizon when the committed stream reaches s_max; speculation
    // must emit exactly the same tokens and the same finish reason (the
    // k_eff cap stops committing at s_max, never one past it)
    let be = backend();
    let cfg = be.man().cfg.clone();
    let y = 10u32;
    let mut rng = Rng::new(41);
    let store = self_loop_store(&*be, y, &mut rng); // never EOS: horizon must bind
    let parent = Arch::parent(cfg.n_layers);
    let prompt = vec![1u32, y];
    let max_new = cfg.s_max; // cannot fit: 2 + 48 > 48
    let oracle = plain_greedy(&be, &store, &parent, &[prompt.clone()], max_new);
    assert_eq!(oracle[0].len(), cfg.s_max - prompt.len(), "oracle must hit the horizon");

    for draft_k in [1usize, 4, 7] {
        let mut sess = SpecSession::new(
            be.clone(),
            &store,
            &parent,
            &store,
            &parent,
            SpecConfig { draft_k, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
        )
        .unwrap();
        let r = sess.generate(&prompt, max_new, SamplingParams::greedy()).unwrap();
        assert_eq!(r.tokens, oracle[0], "k={draft_k}: horizon run must match plain decoding");
        assert_eq!(r.finish, FinishReason::CacheHorizon, "k={draft_k}");
        assert_eq!(sess.kv_allocated_bytes(), (0, 0));
    }
}

#[test]
fn self_drafter_accepts_everything_and_amortizes_k_plus_1() {
    // parent as its own drafter: verification compares bitwise-identical
    // logits, so every draft is accepted — acceptance 1.0 exactly, and
    // each verify pass nets draft_k + 1 tokens, matching the analytic
    // model with zero tolerance. The self-loop store never emits EOS, so
    // the counts are exact.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(33);
    let store = self_loop_store(&*be, y, &mut rng);
    let parent = Arch::parent(be.man().cfg.n_layers);
    let k = 4usize;
    let max_new = 1 + 3 * (k + 1); // one prefill token + exactly 3 full rounds
    let mut sess = SpecSession::new(
        be.clone(),
        &store,
        &parent,
        &store,
        &parent,
        SpecConfig { draft_k: k, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
    )
    .unwrap();
    let r = sess.generate(&[1, y], max_new, SamplingParams::greedy()).unwrap();
    assert_eq!(r.tokens.len(), max_new);
    assert!(r.tokens.iter().all(|&t| t == y), "self-loop store must keep emitting y");
    assert_eq!(r.finish, FinishReason::MaxNew);
    assert_eq!(r.acceptance_rate(), 1.0);
    assert_eq!(r.proposed, 3 * k);
    assert_eq!(r.accepted, 3 * k);
    assert_eq!(r.parent_passes, 4, "1 prefill + 3 verify passes");
    assert_eq!(r.rollbacks, 0, "full acceptance never rolls back");
    assert_eq!(r.tokens_per_verify_pass(), (k + 1) as f64);
    assert_eq!(expected_tokens_per_pass(r.acceptance_rate(), k), (k + 1) as f64);
    // the headline: amortized tokens per parent forward is well above 1
    assert!(r.tokens_per_pass() > 3.0);
    let m = sess.parent_metrics();
    assert_eq!(m.draft_proposed, 3 * k);
    assert_eq!(m.draft_accepted, 3 * k);
    assert_eq!(m.mean_acceptance(), 1.0);
    assert!(m.summary().contains("spec accepted/proposed"));
}

#[test]
fn speedup_model_matches_measured_acceptance_within_tolerance() {
    // A real (imperfect) drafter under stochastic sampling: estimate α̂
    // per attempted position, then check the geometric model's expected
    // tokens per verify pass against the measured value. Stated
    // tolerance: 40% relative + 0.4 absolute slack — the model assumes
    // i.i.d. acceptance, the measurement is a few hundred tokens.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(35);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let k = 4usize;
    let mut sess = SpecSession::new(
        be.clone(),
        &store,
        &parent,
        &store,
        &child,
        SpecConfig { draft_k: k, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
    )
    .unwrap();
    let mut prng = Rng::new(12);
    let (mut tokens, mut verify_passes, mut accepted, mut attempted) = (0usize, 0usize, 0usize, 0usize);
    for i in 0..12u64 {
        let prompt = sample_sequence(&world, &mix, 6, &mut prng);
        let r = sess
            .generate(&prompt, 24, SamplingParams::temperature(0.8).with_seed(100 + i))
            .unwrap();
        tokens += r.tokens.len() - 1; // exclude the prefill token
        verify_passes += r.parent_passes - 1;
        accepted += r.accepted;
        attempted += r.attempted;
    }
    assert!(verify_passes > 0 && attempted > 0);
    let alpha_hat = accepted as f64 / attempted as f64;
    let measured = tokens as f64 / verify_passes as f64;
    let modeled = expected_tokens_per_pass(alpha_hat, k);
    assert!(
        measured >= 1.0 && measured <= (k + 1) as f64,
        "measured tokens/verify-pass out of range: {measured}"
    );
    let err = (modeled - measured).abs();
    assert!(
        err <= 0.40 * measured + 0.4,
        "speedup model off: measured {measured:.3} tok/pass vs modeled {modeled:.3} at α̂ {alpha_hat:.3}"
    );
}

#[test]
fn stochastic_speculation_is_seed_reproducible() {
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(36);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(2);
    let prompt = sample_sequence(&world, &mix, 10, &mut prng);

    let run = |seed: u64| {
        let mut sess = SpecSession::new(
            be.clone(),
            &store,
            &parent,
            &store,
            &child,
            SpecConfig { draft_k: 3, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
        )
        .unwrap();
        sess.generate(&prompt, 12, SamplingParams::temperature(0.9).with_seed(seed))
            .unwrap()
            .tokens
    };
    let a = run(7);
    assert_eq!(a, run(7), "same seed must reproduce the same speculative tokens");
    assert!(a.iter().all(|&t| t < cfg.v as u32));
    let differs = [8u64, 9, 10, 11].iter().any(|&s| run(s) != a);
    assert!(differs, "different seeds must eventually diverge");
}

#[test]
fn engine_rollback_is_exact_recompute() {
    // the engine-level contract behind verification: teacher-force a few
    // tokens, roll back, teacher-force the same tokens again — logits
    // must be bitwise identical (stale cache rows beyond the rewound
    // position are dead because attention masks at the fed position).
    let be = backend();
    let mut rng = Rng::new(37);
    let store = init_parent(be.man(), &mut rng);
    let parent = Arch::parent(be.man().cfg.n_layers);
    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &parent).unwrap();
    let (id, first) = eng.spec_open(&[1, 5, 9]).unwrap();
    assert_eq!(first.len(), be.man().cfg.v);
    let base_len = eng.spec_len(id).unwrap();
    assert_eq!(base_len, 3);
    let kv_base = eng.kv_allocated_bytes();

    let probe = [7u32, 11, 13];
    let rows1 = eng.spec_extend(id, &probe, 0).unwrap();
    assert_eq!(rows1.len(), 3);
    let kv_grown = eng.kv_allocated_bytes();
    assert!(kv_grown >= kv_base);

    eng.spec_truncate(id, base_len).unwrap();
    assert_eq!(eng.spec_len(id).unwrap(), base_len);
    assert_eq!(eng.kv_allocated_bytes(), kv_base, "rollback must free exactly the grown pages");
    assert_eq!(eng.metrics.spec_rollbacks, 1);

    let rows2 = eng.spec_extend(id, &probe, 0).unwrap();
    assert_eq!(rows1, rows2, "recompute after rollback must be bitwise identical");

    // collect_from skips the head for earlier positions
    eng.spec_truncate(id, base_len).unwrap();
    let tail_only = eng.spec_extend(id, &probe, 2).unwrap();
    assert_eq!(tail_only.len(), 1);
    assert_eq!(tail_only[0], rows1[2]);

    eng.spec_close(id);
    assert_eq!(eng.kv_allocated_bytes(), 0);
    assert!(eng.spec_len(id).is_err(), "closed handle must be unknown");
}

#[test]
fn mixed_mode_serving_is_byte_identical_to_isolated_runs() {
    // mixed-mode serving: ONE engine interleaves a plain batched request
    // and an externally driven speculative sequence. Every forward —
    // batched decode steps included — parks unfed live lanes at their
    // own frontier (not position 0), so neither mode perturbs the other:
    // both must be bitwise identical to isolated runs.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(38);
    // self-loop store: the batched request deterministically emits `y`
    // forever, so it cannot finish early and the interleaving below is
    // stable; the spec comparisons are raw logits and need no structure
    let store = self_loop_store(&*be, y, &mut rng);
    let parent = Arch::parent(be.man().cfg.n_layers);
    let mut eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &parent).unwrap();
    assert_eq!(eng.decode_lanes(), 2, "tiny config compiles 2 decode lanes");

    let spec_prompt = vec![1u32, 5, 9];
    let probe = [7u32, 11, 13];
    let batch_prompt = vec![1u32, y];

    // isolated speculative oracle
    let (sid, first_iso) = eng.spec_open(&spec_prompt).unwrap();
    let rows_iso = eng.spec_extend(sid, &probe, 0).unwrap();
    eng.spec_close(sid);
    // isolated batched oracle
    eng.submit(GenRequest::new(batch_prompt.clone(), 6)).unwrap();
    let tokens_iso = eng.run_to_completion().unwrap()[0].tokens.clone();
    assert_eq!(tokens_iso, vec![y; 6], "self-loop store keeps generating y");
    assert_eq!(eng.kv_allocated_bytes(), 0);

    // mixed: the spec sequence opens first, then a batched request joins
    let (sid, first_mix) = eng.spec_open(&spec_prompt).unwrap();
    assert_eq!(first_mix, first_iso, "spec prefill must not see the batched lane");
    eng.submit(GenRequest::new(batch_prompt.clone(), 6)).unwrap();
    eng.step().unwrap(); // admits + decodes the batched slot, spec lane parked
    assert!(eng.active() > 0 && eng.spec_active() > 0, "both modes live on one engine");
    // spec extensions interleave with batched decode steps
    let mut rows_mix = eng.spec_extend(sid, &probe[..1], 0).unwrap();
    eng.step().unwrap();
    rows_mix.extend(eng.spec_extend(sid, &probe[1..], 0).unwrap());
    while !eng.is_idle() {
        eng.step().unwrap();
    }
    let resp = eng.take_finished();
    assert_eq!(resp.len(), 1);
    assert_eq!(resp[0].tokens, tokens_iso, "batched output must ignore the parked spec lane");
    assert_eq!(rows_mix, rows_iso, "spec logits must ignore the interleaved batched steps");

    // rollback + recompute still exact in mixed mode
    eng.spec_truncate(sid, spec_prompt.len()).unwrap();
    let rows_again = eng.spec_extend(sid, &probe, 0).unwrap();
    assert_eq!(rows_again, rows_iso);
    eng.spec_close(sid);
    assert_eq!(eng.kv_allocated_bytes(), 0);

    // lane capacity still binds: spec sequences + batched slots share it
    let (s1, _) = eng.spec_open(&spec_prompt).unwrap();
    let (s2, _) = eng.spec_open(&[1, 2]).unwrap();
    assert!(eng.spec_open(&[3, 4]).is_err(), "no third sequence: every lane is pinned");
    eng.submit(GenRequest::new(batch_prompt.clone(), 2)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.active(), 0, "no lane free: the batched request must wait in queue");
    assert_eq!(eng.queue_len(), 1);
    eng.spec_close(s2);
    eng.step().unwrap();
    assert_eq!(eng.queue_len(), 0, "a freed lane admits the waiting batched request");
    while !eng.is_idle() {
        eng.step().unwrap();
    }
    let resp = eng.take_finished();
    let want = &tokens_iso[..tokens_iso.len().min(2)];
    assert_eq!(resp[0].tokens, want, "max_new 2 prefix of the oracle");
    eng.spec_close(s1);
    assert_eq!(eng.kv_allocated_bytes(), 0);
}

#[test]
fn eos_inside_an_accepted_draft_stops_the_stream() {
    // engineer a chain 1 -> y -> z -> EOS (see serving_integration):
    // with the parent as its own drafter every draft is accepted, so EOS
    // arrives *inside* a draft and must terminate the request exactly
    // there, byte-identical to the plain engine
    let be = backend();
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut rng = Rng::new(39);
    let mut store = init_parent(be.man(), &mut rng);
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    let (y, z) = (10u32, 11u32);
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = |t: u32| (t as usize) * d;
    e.data[row(y)..row(y) + d].fill(0.0);
    e.data[row(y)] = 1.0;
    e.data[row(z)..row(z) + d].fill(0.0);
    e.data[row(z)] = 2.0;
    e.data[row(z) + 1] = 1.0;
    e.data[row(EOS)..row(EOS) + d].fill(0.0);
    e.data[row(EOS) + 1] = 6.0;
    store.put("embed", e);

    let parent = Arch::parent(cfg.n_layers);
    let oracle = plain_greedy(&be, &store, &parent, &[vec![1, y]], 10);
    assert_eq!(oracle[0], vec![z, EOS]);
    let mut sess = SpecSession::new(
        be.clone(),
        &store,
        &parent,
        &store,
        &parent,
        SpecConfig { draft_k: 6, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
    )
    .unwrap();
    let r = sess.generate(&[1, y], 10, SamplingParams::greedy()).unwrap();
    assert_eq!(r.tokens, vec![z, EOS]);
    assert_eq!(r.finish, FinishReason::Eos);
    assert_eq!(sess.kv_allocated_bytes(), (0, 0));
}

#[test]
fn batched_spec_equivalence_matrix() {
    // N ∈ {1, 2, 4} sequences (4 oversubscribes the 2 decode lanes, so
    // the waiting requests backfill as lanes finish) × unchunked and
    // chunked prompts: every sequence in the batch must be byte-identical
    // to plain greedy parent decoding, and both engines must hand back
    // every page.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(51);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(9);
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for len in [4usize, 9, 14] {
        prompts.push(sample_sequence(&world, &mix, len, &mut prng));
    }
    // one prompt past the prefill window: the chunked spec_open path
    prompts.push(sample_sequence(&world, &mix, cfg.s_prefill, &mut prng));
    assert!(prompts.last().unwrap().len() > cfg.s_prefill);

    let max_new = 8usize;
    let oracle = plain_greedy(&be, &store, &parent, &prompts, max_new);

    for n in [1usize, 2, 4] {
        let mut batch = SpecBatch::new(
            be.clone(),
            &store,
            &parent,
            &store,
            &child,
            SpecConfig { draft_k: 3, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
        )
        .unwrap();
        let reqs: Vec<SpecRequest> =
            prompts.iter().take(n).map(|p| SpecRequest::new(p.clone(), max_new)).collect();
        let rs = batch.generate_many(&reqs).unwrap();
        assert_eq!(rs.len(), n);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(
                r.tokens, oracle[i],
                "N={n}, seq {i}: batched greedy speculation must match plain greedy"
            );
            assert!(matches!(r.finish, FinishReason::Eos | FinishReason::MaxNew));
        }
        // exact rollback across the whole batch: no pages may survive
        assert_eq!(batch.kv_allocated_bytes(), (0, 0), "N={n}: KV pages leaked");
        // the verify passes actually took the fused path
        assert!(
            batch.parent_metrics().spec_fused_passes > 0,
            "N={n}: fused multi-token verify must be exercised"
        );
    }
}

#[test]
fn prefix_cache_keeps_specbatch_byte_identical() {
    // the shared-system-prompt regime under batched speculation: with
    // `EngineConfig::prefix_cache` on, BOTH engines (parent verifier and
    // child drafter) retain the first cold prompt's prefix and every
    // later lane imports it instead of re-prefilling — and the output
    // stays byte-identical to plain greedy parent decoding.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(55);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(17);
    // 4 requests over 2 lanes sharing a 17-token system prompt: the
    // first two retain (one per engine tree), the backfilled lanes hit
    let sys = sample_sequence(&world, &mix, 16, &mut prng);
    assert_eq!(sys.len(), 17);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| {
            let mut p = sys.clone();
            p.extend(sample_sequence(&world, &mix, 3 + i, &mut prng));
            p
        })
        .collect();
    let max_new = 8usize;
    let oracle = plain_greedy(&be, &store, &parent, &prompts, max_new);

    let mut batch = SpecBatch::new(
        be.clone(),
        &store,
        &parent,
        &store,
        &child,
        SpecConfig {
            draft_k: 3,
            engine: EngineConfig::new().kv_budget_bytes(32 << 20).prefix_cache(true, 8 << 20),
            ..Default::default()
        },
    )
    .unwrap();
    let reqs: Vec<SpecRequest> =
        prompts.iter().map(|p| SpecRequest::new(p.clone(), max_new)).collect();
    let rs = batch.generate_many(&reqs).unwrap();
    for (i, r) in rs.iter().enumerate() {
        assert_eq!(r.tokens, oracle[i], "seq {i}: prefix-cached speculation must match plain greedy");
    }
    let (psaved, csaved) = batch.prefix_tokens_saved();
    assert!(psaved >= 16, "parent lanes must reuse the retained system prompt (saved {psaved})");
    assert!(csaved >= 16, "drafter lanes must reuse their own retained prefix (saved {csaved})");
    // only the retained segments outlive the batch — request pages are
    // all handed back
    let (pkv, ckv) = batch.kv_allocated_bytes();
    let (pret, cret) = batch.prefix_retained_bytes();
    assert_eq!((pkv, ckv), (pret, cret), "only retained segments may hold bytes after the run");
    assert!(pret > 0 && cret > 0);
}

#[test]
fn fused_verify_matches_sequential_decode_logits() {
    // the Backend contract behind the fused path: the fused multi-token
    // lowering and the sequential per-step lowering must produce
    // bitwise-identical logits rows, for a single sequence and for two
    // sequences extended together (ragged feeds).
    let be = backend();
    let mut rng = Rng::new(52);
    let store = init_parent(be.man(), &mut rng);
    let parent = Arch::parent(be.man().cfg.n_layers);
    let pa = vec![1u32, 5, 9, 2];
    let pb = vec![3u32, 7];
    let feed_a = [11u32, 4, 8, 6, 2];
    let feed_b = [13u32, 10, 1];

    let run = |fused: bool| {
        let mut eng = EngineConfig::new()
            .kv_budget_bytes(32 << 20)
            .fused_verify(fused)
            .build(be.clone(), &store, &parent)
            .unwrap();
        let (ida, first_a) = eng.spec_open(&pa).unwrap();
        let (idb, first_b) = eng.spec_open(&pb).unwrap();
        let rows = eng
            .spec_extend_batch(&[
                SpecFeed { id: ida, tokens: &feed_a, collect_from: 0 },
                SpecFeed { id: idb, tokens: &feed_b, collect_from: 1 },
            ])
            .unwrap();
        let fused_passes = eng.metrics.spec_fused_passes;
        eng.spec_close(ida);
        eng.spec_close(idb);
        assert_eq!(eng.kv_allocated_bytes(), 0);
        (first_a, first_b, rows, fused_passes)
    };
    let (fa1, fb1, rows_fused, fp1) = run(true);
    let (fa2, fb2, rows_seq, fp0) = run(false);
    assert!(fp1 > 0, "fused engine must fuse");
    assert_eq!(fp0, 0, "fused_verify(false) must lower sequentially");
    assert_eq!(fa1, fa2);
    assert_eq!(fb1, fb2);
    assert_eq!(rows_fused.len(), 2);
    assert_eq!(rows_fused[0].len(), feed_a.len());
    assert_eq!(rows_fused[1].len(), feed_b.len() - 1, "collect_from skips early rows");
    assert_eq!(rows_fused, rows_seq, "fused and sequential logits must agree bitwise");

    // batch composition must not change a sequence's logits: a solo run
    // of sequence A gives the same rows as the two-lane batch
    let mut solo = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .build(be.clone(), &store, &parent)
        .unwrap();
    let (id, first) = solo.spec_open(&pa).unwrap();
    assert_eq!(first, fa1);
    let solo_rows = solo.spec_extend(id, &feed_a, 0).unwrap();
    assert_eq!(solo_rows, rows_fused[0], "a co-batched lane must see identical logits");
    solo.spec_close(id);
}

#[test]
fn page_accounting_exact_when_one_lane_rolls_back() {
    // two speculative sequences share the pool; one rolls back while the
    // other advances — the freed bytes must be exactly the rolled-back
    // lane's growth, bit-for-bit in the allocator's accounting.
    let be = backend();
    let mut rng = Rng::new(53);
    let store = init_parent(be.man(), &mut rng);
    let parent = Arch::parent(be.man().cfg.n_layers);
    let mut eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &parent).unwrap();

    // identical 16-token prompts: both sequences hold exactly one page
    // per caching layer (page_len = 16), so growth deltas are symmetric
    let prompt: Vec<u32> = (0..16u32).map(|i| i % 7 + 1).collect();
    let (s1, _) = eng.spec_open(&prompt).unwrap();
    let per_seq = eng.kv_allocated_bytes();
    assert!(per_seq > 0);
    let (s2, _) = eng.spec_open(&prompt).unwrap();
    let b0 = eng.kv_allocated_bytes();
    assert_eq!(b0, 2 * per_seq, "identical prompts must book identical pages");

    // batch-extend both across a page boundary (16 -> 33 positions)
    let ext: Vec<u32> = (0..17u32).map(|i| i % 5 + 1).collect();
    eng.spec_extend_batch(&[
        SpecFeed { id: s1, tokens: &ext, collect_from: ext.len() },
        SpecFeed { id: s2, tokens: &ext, collect_from: ext.len() },
    ])
    .unwrap();
    let b1 = eng.kv_allocated_bytes();
    assert!(b1 > b0);
    assert_eq!((b1 - b0) % 2, 0, "symmetric extensions must book symmetric pages");
    let per_ext = (b1 - b0) / 2;

    // lane 1 rolls back to its prompt; lane 2 keeps its extension
    eng.spec_truncate(s1, 16).unwrap();
    assert_eq!(eng.spec_len(s1).unwrap(), 16);
    assert_eq!(eng.spec_len(s2).unwrap(), 33);
    assert_eq!(
        eng.kv_allocated_bytes(),
        b0 + per_ext,
        "rollback must free exactly the rolled-back lane's growth"
    );

    // the rolled-back lane can re-extend while the other is parked, and
    // re-booking costs exactly what it freed
    eng.spec_extend_batch(&[SpecFeed { id: s1, tokens: &ext, collect_from: ext.len() }]).unwrap();
    assert_eq!(eng.kv_allocated_bytes(), b1);

    eng.spec_close(s1);
    assert_eq!(eng.kv_allocated_bytes(), per_seq + per_ext, "lane 2 must be untouched");
    eng.spec_close(s2);
    assert_eq!(eng.kv_allocated_bytes(), 0);
}

#[test]
fn adaptive_draft_k_keeps_greedy_equivalence() {
    // online draft-length tuning only gates wall-clock: with adaptation
    // armed, batched greedy speculation stays byte-identical to plain
    // greedy decoding (the invariant is per position, not per k)
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(54);
    let mut store = init_parent(be.man(), &mut rng);
    let child = child_arch(&*be, &mut store);
    let parent = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(13);
    let prompts: Vec<Vec<u32>> =
        [5usize, 8, 11].iter().map(|&l| sample_sequence(&world, &mix, l, &mut prng)).collect();
    let max_new = 12usize;
    let oracle = plain_greedy(&be, &store, &parent, &prompts, max_new);

    let mut batch = SpecBatch::new(
        be.clone(),
        &store,
        &parent,
        &store,
        &child,
        SpecConfig {
            draft_k: 4,
            adapt_k_max: Some(6),
            engine: EngineConfig::new().kv_budget_bytes(32 << 20),
        },
    )
    .unwrap();
    let reqs: Vec<SpecRequest> =
        prompts.iter().map(|p| SpecRequest::new(p.clone(), max_new)).collect();
    let rs = batch.generate_many(&reqs).unwrap();
    for (i, r) in rs.iter().enumerate() {
        assert_eq!(r.tokens, oracle[i], "adaptive k must not change content (seq {i})");
    }
    assert_eq!(batch.kv_allocated_bytes(), (0, 0));
    let k = batch.current_draft_k();
    assert!((1..=6).contains(&k), "tuned k must stay within 1..=k_max, got {k}");
    assert!(batch.observed_alpha() >= 0.0 && batch.observed_alpha() <= 1.0);
}
