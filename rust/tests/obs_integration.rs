//! Observability integration tests: trace determinism (same trace spec +
//! seed on the virtual clock serializes to byte-identical JSONL, per
//! serving configuration), per-request span reconstruction (queued +
//! prefill + decode tiles e2e exactly), event-kind coverage per config,
//! the overhead-accounting regression for every serving mode (satellite
//! of DESIGN.md §11), and the Prometheus scrape round-trip through the
//! async server's control channel. Hermetic (RefBackend + tiny manifest).

use puzzle::arch::Arch;
use puzzle::obs::{jsonl, request_spans, Event, TraceLog, Tracer, DEFAULT_RING_CAP};
use puzzle::runtime::{share, SharedBackend};
use puzzle::serving::{EngineConfig, GenRequest};
use puzzle::specdec::{SpecBatch, SpecConfig, SpecRequest};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;
use puzzle::weights::Store;
use puzzle::workload::{replay, MixKind, Server, Trace, TraceSpec, WorkloadRun};

#[cfg(not(feature = "pjrt"))]
fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

#[cfg(feature = "pjrt")]
fn backend() -> SharedBackend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    share(puzzle::runtime::XlaBackend::open(&dir).unwrap())
}

fn setup() -> (SharedBackend, Store, Arch, Trace) {
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(1);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let trace =
        TraceSpec::small(MixKind::MultiTurn, 7).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    (be, store, arch, trace)
}

fn engine_cfg(prefix: bool) -> EngineConfig {
    EngineConfig::new().kv_budget_bytes(16 << 20).page_len(4).prefix_cache(prefix, 8 << 20)
}

/// One fresh replay of `trace` under the named configuration with a
/// virtual-clock tracer attached; returns the run and the trace log.
fn traced_run(
    be: &SharedBackend,
    store: &Store,
    arch: &Arch,
    trace: &Trace,
    config: &str,
    tracer: Tracer,
) -> (WorkloadRun, TraceLog) {
    let run = match config {
        "plain" => {
            let mut eng =
                engine_cfg(false).tracer(tracer.clone()).build(be.clone(), store, arch).unwrap();
            replay(trace, &mut Server::Engine(&mut eng), config).unwrap()
        }
        "prefix_cache" => {
            let mut eng =
                engine_cfg(true).tracer(tracer.clone()).build(be.clone(), store, arch).unwrap();
            replay(trace, &mut Server::Engine(&mut eng), config).unwrap()
        }
        "speculative" => {
            let cfg = SpecConfig {
                draft_k: 3,
                adapt_k_max: None,
                engine: engine_cfg(true).tracer(tracer.clone()),
            };
            let mut batch = SpecBatch::new(be.clone(), store, arch, store, arch, cfg).unwrap();
            replay(trace, &mut Server::Spec(&mut batch), config).unwrap()
        }
        other => panic!("unknown test config {other}"),
    };
    (run, tracer.snapshot())
}

#[test]
fn traced_jsonl_is_byte_identical_per_configuration_and_does_not_perturb_serving() {
    let (be, store, arch, trace) = setup();
    for config in ["plain", "prefix_cache", "speculative"] {
        let (run_a, log_a) =
            traced_run(&be, &store, &arch, &trace, config, Tracer::virtual_ticks(DEFAULT_RING_CAP));
        let (run_b, log_b) =
            traced_run(&be, &store, &arch, &trace, config, Tracer::virtual_ticks(DEFAULT_RING_CAP));
        assert!(!log_a.recs.is_empty(), "{config}: traced replay must record events");
        assert_eq!(log_a.dropped, 0, "{config}: the default ring must hold a small trace");
        assert_eq!(
            jsonl(&log_a),
            jsonl(&log_b),
            "{config}: same trace + seed must serialize byte-identically"
        );
        // tracing must observe, never steer: the scored replay is
        // identical to an untraced run of the same configuration
        let (run_c, log_c) = traced_run(&be, &store, &arch, &trace, config, Tracer::disabled());
        assert!(log_c.recs.is_empty());
        assert_eq!(run_a.event_log, run_c.event_log, "{config}: tracing perturbed the replay");
        assert_eq!(run_a.ticks, run_c.ticks);
        assert_eq!(run_a.event_log, run_b.event_log);
    }
}

#[test]
fn request_spans_tile_e2e_exactly_on_the_virtual_clock() {
    let (be, store, arch, trace) = setup();
    let (run, log) = traced_run(
        &be,
        &store,
        &arch,
        &trace,
        "prefix_cache",
        Tracer::virtual_ticks(DEFAULT_RING_CAP),
    );
    let spans = request_spans(&log);
    assert_eq!(
        spans.iter().filter(|s| s.finish_us.is_some()).count(),
        run.completed(),
        "every completed request reconstructs a finished span"
    );
    let mut full = 0;
    for s in &spans {
        if let (Some(q), Some(p), Some(d), Some(e)) =
            (s.queued_us(), s.prefill_us(), s.decode_us(), s.e2e_us())
        {
            assert_eq!(q + p + d, e, "req {}: spans must partition e2e exactly", s.id);
            full += 1;
        }
    }
    assert!(full > 0, "the replay must produce fully bounded spans");
}

#[test]
fn event_kinds_cover_their_configurations() {
    let (be, store, arch, trace) = setup();
    let has = |log: &TraceLog, f: &dyn Fn(&Event) -> bool| log.recs.iter().any(|r| f(&r.ev));

    let (_, plain) =
        traced_run(&be, &store, &arch, &trace, "plain", Tracer::virtual_ticks(DEFAULT_RING_CAP));
    assert!(has(&plain, &|e| matches!(e, Event::Submitted { .. })));
    assert!(has(&plain, &|e| matches!(e, Event::Step { .. })));
    assert!(has(&plain, &|e| matches!(e, Event::PrefillChunk { .. })));
    assert!(has(&plain, &|e| matches!(e, Event::FirstToken { .. })));
    assert!(has(&plain, &|e| matches!(e, Event::Finished { .. })));
    assert!(
        !has(&plain, &|e| matches!(e, Event::Admitted { hit: true, .. })),
        "no prefix cache, no hits"
    );

    let (_, warm) = traced_run(
        &be,
        &store,
        &arch,
        &trace,
        "prefix_cache",
        Tracer::virtual_ticks(DEFAULT_RING_CAP),
    );
    assert!(
        has(&warm, &|e| matches!(e, Event::Admitted { hit: true, .. })),
        "multi-turn prompts must record prefix-hit admissions"
    );

    let (_, spec) = traced_run(
        &be,
        &store,
        &arch,
        &trace,
        "speculative",
        Tracer::virtual_ticks(DEFAULT_RING_CAP),
    );
    assert!(
        has(&spec, &|e| matches!(e, Event::SpecRound { .. })),
        "speculative serving must record draft/verify rounds"
    );
    assert!(has(&spec, &|e| matches!(
        e,
        Event::SpecRound { drafted, accepted, rolled_back, .. }
            if *drafted == *accepted + *rolled_back
    )));
    assert!(
        has(&spec, &|e| matches!(e, Event::Admitted { hit: true, .. })),
        "the speculative config runs the prefix cache on both engines"
    );
}

/// Satellite regression: every serving mode accrues both wall time and
/// backend execute time, so `overhead_frac` is meaningful (< 1.0) whenever
/// any forward ran — including fused speculative verification and budgeted
/// chunked prefill.
#[test]
fn overhead_accounting_covers_every_serving_mode() {
    let (be, store, arch, _) = setup();
    let cfg = be.man().cfg.clone();
    let check = |label: &str, m: &puzzle::serving::EngineMetrics| {
        assert!(m.wall_secs > 0.0, "{label}: wall time must accrue");
        assert!(m.execute_secs > 0.0, "{label}: backend execute time must accrue");
        assert!(m.overhead_frac() < 1.0, "{label}: overhead cannot swallow all wall time");
    };

    // plain batched decode
    let mut eng = engine_cfg(false).build(be.clone(), &store, &arch).unwrap();
    eng.submit(GenRequest::new(vec![1, 2, 3, 4], 6)).unwrap();
    eng.run_to_completion().unwrap();
    check("plain", &eng.metrics);

    // budgeted chunked prefill: the prompt outlives the per-step budget
    let mut eng = engine_cfg(false)
        .prefill_budget(4)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let prompt: Vec<u32> = (0..cfg.s_prefill + 6).map(|i| (i % (cfg.v - 2)) as u32 + 1).collect();
    eng.submit(GenRequest::new(prompt, 4)).unwrap();
    eng.run_to_completion().unwrap();
    assert!(eng.metrics.prefill_chunk_passes > 0, "the budget must actually chunk");
    check("chunked", &eng.metrics);

    // speculative draft/verify (fused multi-token verification passes)
    let scfg = SpecConfig { draft_k: 3, adapt_k_max: None, engine: engine_cfg(false) };
    let mut batch = SpecBatch::new(be.clone(), &store, &arch, &store, &arch, scfg).unwrap();
    batch.generate_many(&[SpecRequest::new(vec![1, 2, 3, 4], 8)]).unwrap();
    assert!(batch.parent_metrics().spec_fused_passes > 0, "verification must run fused");
    check("speculative", batch.parent_metrics());
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn metrics_text_round_trips_over_the_control_channel() {
    use puzzle::obs::scrape_value;
    use puzzle::server::AsyncServer;

    let (be, store, arch, _) = setup();
    let eng = engine_cfg(true).prefill_budget(4).build(be.clone(), &store, &arch).unwrap();
    let server = AsyncServer::spawn(eng);
    let handle = server.handle();
    let prompt: Vec<u32> = (0..be.man().cfg.s_prefill + 6).map(|i| (i % 11) as u32 + 1).collect();
    for _ in 0..2 {
        let stream = handle.submit(GenRequest::new(prompt.clone(), 5)).unwrap();
        let (tokens, finish) = stream.collect();
        assert!(finish.is_some());
        assert!(!tokens.is_empty());
    }
    let text = handle.metrics_text().unwrap();
    drop(handle);
    let eng = server.shutdown();

    // the scrape carries the engine counters (prefix / spec / chunk
    // sections included) plus the worker's live occupancy gauges
    assert_eq!(scrape_value(&text, "puzzle_requests_completed_total"), Some(2.0));
    assert_eq!(
        scrape_value(&text, "puzzle_generated_tokens_total"),
        Some(eng.metrics.generated_tokens as f64)
    );
    assert_eq!(
        scrape_value(&text, "puzzle_prefill_chunk_passes_total"),
        Some(eng.metrics.prefill_chunk_passes as f64)
    );
    assert_eq!(
        scrape_value(&text, "puzzle_prefix_hits_total"),
        Some(eng.metrics.prefix_hits as f64)
    );
    assert_eq!(
        scrape_value(&text, "puzzle_draft_proposed_total"),
        Some(0.0),
        "the plain engine proposes no drafts"
    );
    assert_eq!(scrape_value(&text, "puzzle_active_lanes"), Some(0.0), "scraped while idle");
    assert_eq!(scrape_value(&text, "puzzle_queue_depth"), Some(0.0));
    assert!(
        scrape_value(&text, "puzzle_kv_allocated_bytes").is_some(),
        "occupancy gauges must render"
    );
    assert!(text.contains("# TYPE puzzle_ttft_seconds histogram"));
    assert!(scrape_value(&text, "puzzle_ttft_seconds_count").unwrap_or(0.0) >= 2.0);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn traced_scrape_carries_ring_loss_and_single_ring_slo_gauges() {
    use puzzle::obs::scrape_value;
    use puzzle::server::AsyncServer;

    let (be, store, arch, _) = setup();
    let eng = engine_cfg(true)
        .tracer(Tracer::wall(DEFAULT_RING_CAP))
        .build(be.clone(), &store, &arch)
        .unwrap();
    let server = AsyncServer::spawn(eng);
    let handle = server.handle();
    for i in 0..2u32 {
        let stream = handle.submit(GenRequest::new(vec![1, 2 + i, 3, 4, 5], 5)).unwrap();
        assert!(stream.collect().1.is_some());
    }
    let text = handle.metrics_text().unwrap();
    drop(handle);
    server.shutdown();

    assert_eq!(
        scrape_value(&text, "puzzle_trace_dropped_events"),
        Some(0.0),
        "a traced engine's scrape must expose the ring-loss counter"
    );
    assert_eq!(
        scrape_value(&text, "puzzle_slo_window_requests_1m"),
        Some(2.0),
        "both finishes fold into the short burn window at scrape time"
    );
    // wall profiles on a wall tracer; a tiny hermetic engine finishes
    // far inside the 30 s lenient TTFT budget
    assert_eq!(scrape_value(&text, "puzzle_slo_wall_lenient_goodput_1m"), Some(1.0));
    assert_eq!(scrape_value(&text, "puzzle_slo_wall_lenient_burn_rate_1m"), Some(0.0));
    assert!(
        scrape_value(&text, "puzzle_slo_wall_strict_burn_rate_5m").is_some(),
        "every profile/window pair must render"
    );
}
