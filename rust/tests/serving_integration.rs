//! Serving-engine integration tests over real artifacts: batching,
//! variable-GQA caches, backpressure, and decode/prefill numerical
//! consistency through the engine path.

use std::path::Path;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::Registry;
use puzzle::serving::Engine;
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;
use puzzle::weights::Store;

fn registry() -> Registry {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    Registry::open(&dir).unwrap()
}

fn variable_arch(reg: &Registry, store: &mut Store) -> Arch {
    let n = reg.man.cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: v };
                bld::init_job_weights(&reg.man, store, &job, None).unwrap();
            }
        }
    }
    arch
}

#[test]
fn engine_serves_batched_requests_on_variable_gqa_arch() {
    let reg = registry();
    let mut rng = Rng::new(1);
    let mut store = init_parent(&reg.man, &mut rng);
    let arch = variable_arch(&reg, &mut store);
    let mut eng = Engine::new(&reg, &store, &arch, 32 << 20).unwrap();
    let world = World::new(2, reg.man.cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let n_req = reg.man.cfg.b_decode * 2 + 1; // forces continuous batching
    for _ in 0..n_req {
        let prompt = sample_sequence(&world, &mix, 8, &mut rng);
        eng.submit(prompt, 6);
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), n_req);
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 6);
        assert!(r.tokens.iter().all(|&t| t < reg.man.cfg.v as u32));
        assert!(r.ttft_secs > 0.0 && r.e2e_secs >= r.ttft_secs);
    }
    assert_eq!(eng.metrics.requests_completed, n_req);
    assert!(eng.metrics.gen_throughput() > 0.0);
}

#[test]
fn engine_greedy_generation_is_deterministic() {
    let reg = registry();
    let mut rng = Rng::new(3);
    let mut store = init_parent(&reg.man, &mut rng);
    let arch = variable_arch(&reg, &mut store);
    let world = World::new(2, reg.man.cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(9);
    let prompt = sample_sequence(&world, &mix, 10, &mut prng);

    let run = |reg: &Registry| {
        let mut eng = Engine::new(reg, &store, &arch, 32 << 20).unwrap();
        eng.submit(prompt.clone(), 8);
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let a = run(&reg);
    let b = run(&reg);
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn engine_decode_matches_prefill_continuation() {
    // serve the same prompt twice: once with max_new 1 (pure prefill) and
    // once with more tokens; the first generated token must agree.
    let reg = registry();
    let mut rng = Rng::new(4);
    let store = init_parent(&reg.man, &mut rng);
    let arch = Arch::parent(reg.man.cfg.n_layers);
    let world = World::new(5, reg.man.cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(2);
    let prompt = sample_sequence(&world, &mix, 12, &mut prng);

    let gen = |max_new: usize| {
        let mut eng = Engine::new(&reg, &store, &arch, 32 << 20).unwrap();
        eng.submit(prompt.clone(), max_new);
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let short = gen(1);
    let long = gen(5);
    assert_eq!(short[0], long[0], "first token must not depend on horizon");
}

#[test]
fn backpressure_defers_but_completes_all() {
    let reg = registry();
    let mut rng = Rng::new(6);
    let store = init_parent(&reg.man, &mut rng);
    let arch = Arch::parent(reg.man.cfg.n_layers);
    // tiny KV budget: roughly one sequence's worth
    let per_pos = {
        use puzzle::serving::kvcache::{PageCfg, PagedKvManager};
        let mgr = PagedKvManager::new(&reg.man, &arch, PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 });
        mgr.bytes_per_position()
    };
    let budget = per_pos * (reg.man.cfg.s_max + 8);
    let mut eng = Engine::new(&reg, &store, &arch, budget).unwrap();
    let world = World::new(5, reg.man.cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    for _ in 0..4 {
        let prompt = sample_sequence(&world, &mix, 6, &mut rng);
        eng.submit(prompt, 4);
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), 4, "backpressure must defer, not drop");
}
