//! Serving-engine integration tests: batching, variable-GQA caches,
//! backpressure, prompt chunking, EOS termination, and decode/prefill
//! numerical consistency through the engine path. Hermetic by default
//! (RefBackend + synthetic manifest); with the `pjrt` feature the same
//! tests run over the AOT artifacts.

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::world::EOS;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::Backend;
use puzzle::serving::Engine;
use puzzle::util::Rng;
use puzzle::weights::store::{block_key, init_parent};
use puzzle::weights::Store;

#[cfg(not(feature = "pjrt"))]
fn backend() -> impl Backend {
    puzzle::runtime::RefBackend::tiny()
}

#[cfg(feature = "pjrt")]
fn backend() -> impl Backend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    puzzle::runtime::XlaBackend::open(&dir).unwrap()
}

fn variable_arch(be: &dyn Backend, store: &mut Store) -> Arch {
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: v };
                bld::init_job_weights(be.man(), store, &job, None).unwrap();
            }
        }
    }
    arch
}

#[test]
fn engine_serves_batched_requests_on_variable_gqa_arch() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(1);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(be, &mut store);
    let mut eng = Engine::new(be, &store, &arch, 32 << 20).unwrap();
    let world = World::new(2, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let n_req = be.man().cfg.b_decode * 2 + 1; // forces continuous batching
    for _ in 0..n_req {
        let prompt = sample_sequence(&world, &mix, 8, &mut rng);
        eng.submit(prompt, 6).unwrap();
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), n_req);
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 6);
        assert!(r.tokens.iter().all(|&t| t < be.man().cfg.v as u32));
        assert!(r.ttft_secs > 0.0 && r.e2e_secs >= r.ttft_secs);
    }
    assert_eq!(eng.metrics.requests_completed, n_req);
    assert!(eng.metrics.gen_throughput() > 0.0);
}

#[test]
fn engine_greedy_generation_is_deterministic() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(3);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(be, &mut store);
    let world = World::new(2, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(9);
    let prompt = sample_sequence(&world, &mix, 10, &mut prng);

    let run = |be: &dyn Backend| {
        let mut eng = Engine::new(be, &store, &arch, 32 << 20).unwrap();
        eng.submit(prompt.clone(), 8).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let a = run(be);
    let b = run(be);
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn engine_decode_matches_prefill_continuation() {
    // serve the same prompt twice: once with max_new 1 (pure prefill) and
    // once with more tokens; the first generated token must agree.
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(4);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(2);
    let prompt = sample_sequence(&world, &mix, 12, &mut prng);

    let gen = |max_new: usize| {
        let mut eng = Engine::new(be, &store, &arch, 32 << 20).unwrap();
        eng.submit(prompt.clone(), max_new).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let short = gen(1);
    let long = gen(5);
    assert_eq!(short[0], long[0], "first token must not depend on horizon");
}

#[test]
fn backpressure_defers_but_completes_all() {
    let be = backend();
    let be: &dyn Backend = &be;
    let mut rng = Rng::new(6);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    // tiny KV budget: roughly one sequence's worth
    let per_pos = {
        use puzzle::serving::kvcache::{PageCfg, PagedKvManager};
        let mgr = PagedKvManager::new(be.man(), &arch, PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 });
        mgr.bytes_per_position()
    };
    let budget = per_pos * (be.man().cfg.s_max + 8);
    let mut eng = Engine::new(be, &store, &arch, budget).unwrap();
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    for _ in 0..4 {
        let prompt = sample_sequence(&world, &mix, 6, &mut rng);
        eng.submit(prompt, 4).unwrap();
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), 4, "backpressure must defer, not drop");
}

#[test]
fn long_prompts_are_chunked_not_truncated() {
    // a prompt longer than the prefill window must be ingested exactly:
    // continuing prompt A with its own first generated token must
    // reproduce the rest of A's continuation (greedy decoding is
    // self-consistent), which fails if the tail were silently dropped.
    let be = backend();
    let be: &dyn Backend = &be;
    let cfg = be.man().cfg.clone();
    let sp = cfg.s_prefill;
    let mut rng = Rng::new(7);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();

    let gen = |prompt: Vec<u32>, max_new: usize| {
        let mut eng = Engine::new(be, &store, &arch, 64 << 20).unwrap();
        eng.submit(prompt, max_new).unwrap();
        let resp = eng.run_to_completion().unwrap();
        (resp[0].tokens.clone(), eng.metrics.chunked_prefills)
    };

    // find a seed whose continuation is long enough to compare
    let mut prompt = Vec::new();
    let mut full = Vec::new();
    for seed in 0..20u64 {
        let mut prng = Rng::new(seed);
        let p = sample_sequence(&world, &mix, sp, &mut prng);
        assert_eq!(p.len(), sp + 1);
        let p = p[..sp].to_vec(); // exactly the prefill window: not chunked
        let (toks, chunked) = gen(p.clone(), 6);
        assert_eq!(chunked, 0, "window-sized prompt must not chunk");
        if toks.len() >= 3 {
            prompt = p;
            full = toks;
            break;
        }
    }
    assert!(full.len() >= 3, "no prompt produced a long enough continuation");

    // extend the prompt past the window with the first generated token
    let mut longer = prompt.clone();
    longer.push(full[0]);
    assert_eq!(longer.len(), sp + 1, "now one token past the prefill window");
    let (cont, chunked) = gen(longer, full.len() - 1);
    assert_eq!(chunked, 1, "over-window prompt must take the chunked path");
    assert_eq!(
        cont,
        full[1..].to_vec(),
        "chunked ingestion must reproduce the un-chunked continuation"
    );
}

#[test]
fn oversized_and_empty_prompts_are_rejected() {
    let be = backend();
    let be: &dyn Backend = &be;
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(8);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut eng = Engine::new(be, &store, &arch, 32 << 20).unwrap();
    assert!(eng.submit(vec![], 4).is_err(), "empty prompt must be rejected");
    let huge = vec![1u32; cfg.s_max];
    assert!(eng.submit(huge, 4).is_err(), "prompt filling the horizon must be rejected");
    assert_eq!(eng.metrics.rejected_prompts, 2);
    // a prompt one token shorter than the horizon is admissible
    let ok = vec![1u32; cfg.s_max - 1];
    eng.submit(ok, 2).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.len(), 1);
    assert_eq!(resp[0].tokens.len(), 1, "only one position left before the horizon");
}

#[test]
fn generation_stops_at_eos_through_the_decode_path() {
    // engineer weights so the model deterministically generates
    // token-chain y -> z -> EOS: residual blocks are zeroed (wo = wd = 0),
    // so the hidden state at each position is the token's embedding, and
    // the tied head makes E rows steer the chain.
    let be = backend();
    let be: &dyn Backend = &be;
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut rng = Rng::new(9);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);

    // zero the output projections: every block becomes the identity
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    // craft the embedding: rows are near-zero noise except the chain rows
    let (y, z) = (10u32, 11u32);
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = |t: u32| (t as usize) * d;
    e.data[row(y)..row(y) + d].fill(0.0);
    e.data[row(y)] = 1.0; // E[y] = e1
    e.data[row(z)..row(z) + d].fill(0.0);
    e.data[row(z)] = 2.0; // E[z] = 2*e1 + e2: from y, z scores highest
    e.data[row(z) + 1] = 1.0;
    e.data[row(EOS)..row(EOS) + d].fill(0.0);
    e.data[row(EOS) + 1] = 6.0; // from z, EOS scores highest
    store.put("embed", e);

    let mut eng = Engine::new(be, &store, &arch, 32 << 20).unwrap();
    eng.submit(vec![1, y], 10).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.len(), 1);
    assert_eq!(
        resp[0].tokens,
        vec![z, EOS],
        "must generate z from prefill, then EOS through a decode step, then stop"
    );
    assert_eq!(eng.metrics.generated_tokens, 2);
    assert!(eng.metrics.decode_steps >= 1, "EOS must be produced by the decode path");
}
