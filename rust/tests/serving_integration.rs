//! Serving-engine integration tests over the v2 API: batching,
//! variable-GQA caches, scheduler policies, backpressure, cancellation,
//! per-request sampling, step-driven streaming, prompt chunking, EOS
//! termination, and decode/prefill numerical consistency. Hermetic by
//! default (RefBackend + synthetic manifest); with the `pjrt` feature the
//! same tests run over the AOT artifacts.

use std::collections::HashMap;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::world::EOS;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{share, Backend, SharedBackend};
use puzzle::serving::kvcache::{PageCfg, PagedKvManager};
use puzzle::serving::{EngineConfig, FinishReason, GenRequest, SamplingParams, SchedulerKind, StreamEvent};
use puzzle::util::Rng;
use puzzle::weights::store::{block_key, init_parent};
use puzzle::weights::Store;

#[cfg(not(feature = "pjrt"))]
fn backend() -> SharedBackend {
    share(puzzle::runtime::RefBackend::tiny())
}

#[cfg(feature = "pjrt")]
fn backend() -> SharedBackend {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    assert!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    share(puzzle::runtime::XlaBackend::open(&dir).unwrap())
}

fn variable_arch(be: &dyn Backend, store: &mut Store) -> Arch {
    let n = be.man().cfg.n_layers;
    let mut arch = Arch::parent(n);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..n {
        for (kind, v) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if v != "gqa_r1" && v != "r100" && v != "noop" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: v };
                bld::init_job_weights(be.man(), store, &job, None).unwrap();
            }
        }
    }
    arch
}

/// Zero every residual block and craft the embedding so the model
/// deterministically self-loops on token `y` (never EOS): the hidden
/// state at each position is the token's embedding, and E[y] is the only
/// row with significant mass along e1, so from y the argmax is y again.
/// Used by tests that need a sequence to stay alive mid-generation.
fn self_loop_store(be: &dyn Backend, y: u32, rng: &mut Rng) -> Store {
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut store = init_parent(be.man(), rng);
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = (y as usize) * d;
    e.data[row..row + d].fill(0.0);
    e.data[row] = 1.0; // E[y] = e1: from y, y itself scores highest
    store.put("embed", e);
    store
}

#[test]
fn engine_serves_batched_requests_on_variable_gqa_arch() {
    let be = backend();
    let mut rng = Rng::new(1);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store);
    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let world = World::new(2, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let n_req = be.man().cfg.b_decode * 2 + 1; // forces continuous batching
    for _ in 0..n_req {
        let prompt = sample_sequence(&world, &mix, 8, &mut rng);
        eng.submit(GenRequest::new(prompt, 6)).unwrap();
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), n_req);
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 6);
        assert!(r.tokens.iter().all(|&t| t < be.man().cfg.v as u32));
        assert!(r.ttft_secs > 0.0 && r.e2e_secs >= r.ttft_secs);
        assert!(matches!(r.finish, FinishReason::Eos | FinishReason::MaxNew));
    }
    assert_eq!(eng.metrics.requests_completed, n_req);
    assert_eq!(eng.metrics.finished_eos + eng.metrics.finished_max_new, n_req);
    assert!(eng.metrics.gen_throughput() > 0.0);
}

#[test]
fn engine_greedy_generation_is_deterministic() {
    let be = backend();
    let mut rng = Rng::new(3);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store);
    let world = World::new(2, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(9);
    let prompt = sample_sequence(&world, &mix, 10, &mut prng);

    let run = |be: &SharedBackend| {
        let mut eng =
            EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
        eng.submit(GenRequest::new(prompt.clone(), 8)).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let a = run(&be);
    let b = run(&be);
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn engine_decode_matches_prefill_continuation() {
    // serve the same prompt twice: once with max_new 1 (pure prefill) and
    // once with more tokens; the first generated token must agree.
    let be = backend();
    let mut rng = Rng::new(4);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(2);
    let prompt = sample_sequence(&world, &mix, 12, &mut prng);

    let gen = |max_new: usize| {
        let mut eng =
            EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
        eng.submit(GenRequest::new(prompt.clone(), max_new)).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let short = gen(1);
    let long = gen(5);
    assert_eq!(short[0], long[0], "first token must not depend on horizon");
}

#[test]
fn backpressure_defers_but_completes_all() {
    let be = backend();
    let mut rng = Rng::new(6);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    // tiny KV budget: roughly one sequence's worth
    let per_pos = {
        let mgr = PagedKvManager::new(be.man(), &arch, PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 });
        mgr.bytes_per_position()
    };
    let budget = per_pos * (be.man().cfg.s_max + 8);
    let mut eng = EngineConfig::new().kv_budget_bytes(budget).build(be.clone(), &store, &arch).unwrap();
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    for _ in 0..4 {
        let prompt = sample_sequence(&world, &mix, 6, &mut rng);
        eng.submit(GenRequest::new(prompt, 4)).unwrap();
    }
    let responses = eng.run_to_completion().unwrap();
    assert_eq!(responses.len(), 4, "backpressure must defer, not drop");
}

#[test]
fn long_prompts_are_chunked_not_truncated() {
    // a prompt longer than the prefill window must be ingested exactly:
    // continuing prompt A with its own first generated token must
    // reproduce the rest of A's continuation (greedy decoding is
    // self-consistent), which fails if the tail were silently dropped.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let sp = cfg.s_prefill;
    let mut rng = Rng::new(7);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let world = World::new(5, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();

    let gen = |prompt: Vec<u32>, max_new: usize| {
        let mut eng =
            EngineConfig::new().kv_budget_bytes(64 << 20).build(be.clone(), &store, &arch).unwrap();
        eng.submit(GenRequest::new(prompt, max_new)).unwrap();
        let resp = eng.run_to_completion().unwrap();
        (resp[0].tokens.clone(), eng.metrics.chunked_prefills)
    };

    // find a seed whose continuation is long enough to compare
    let mut prompt = Vec::new();
    let mut full = Vec::new();
    for seed in 0..20u64 {
        let mut prng = Rng::new(seed);
        let p = sample_sequence(&world, &mix, sp, &mut prng);
        assert_eq!(p.len(), sp + 1);
        let p = p[..sp].to_vec(); // exactly the prefill window: not chunked
        let (toks, chunked) = gen(p.clone(), 6);
        assert_eq!(chunked, 0, "window-sized prompt must not chunk");
        if toks.len() >= 3 {
            prompt = p;
            full = toks;
            break;
        }
    }
    assert!(full.len() >= 3, "no prompt produced a long enough continuation");

    // extend the prompt past the window with the first generated token
    let mut longer = prompt.clone();
    longer.push(full[0]);
    assert_eq!(longer.len(), sp + 1, "now one token past the prefill window");
    let (cont, chunked) = gen(longer, full.len() - 1);
    assert_eq!(chunked, 1, "over-window prompt must take the chunked path");
    assert_eq!(
        cont,
        full[1..].to_vec(),
        "chunked ingestion must reproduce the un-chunked continuation"
    );
}

#[test]
fn unservable_requests_are_rejected_at_submit() {
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(8);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    assert!(eng.submit(GenRequest::new(vec![], 4)).is_err(), "empty prompt must be rejected");
    let huge = vec![1u32; cfg.s_max];
    assert!(
        eng.submit(GenRequest::new(huge, 4)).is_err(),
        "prompt filling the horizon must be rejected"
    );
    assert!(
        eng.submit(GenRequest::new(vec![1, 3], 0)).is_err(),
        "max_new == 0 must be rejected (prefill always samples one token)"
    );
    assert_eq!(eng.metrics.rejected_prompts, 3);
    // each rejection surfaced as a StreamEvent::Rejected on the next step
    let events = eng.step().unwrap();
    assert_eq!(
        events.iter().filter(|e| matches!(e, StreamEvent::Rejected { .. })).count(),
        3
    );
    // a prompt one token shorter than the horizon is admissible
    let ok = vec![1u32; cfg.s_max - 1];
    eng.submit(GenRequest::new(ok, 2)).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.len(), 1);
    assert_eq!(resp[0].tokens.len(), 1, "only one position left before the horizon");
    assert!(matches!(resp[0].finish, FinishReason::Eos | FinishReason::CacheHorizon | FinishReason::MaxNew));
}

#[test]
fn over_budget_horizon_is_rejected_at_submit_not_stalled() {
    // v1 accepted any request that fit s_max and only failed later with
    // "engine stalled"; v2 rejects a horizon whose pages exceed the total
    // budget right at submit.
    let be = backend();
    let mut rng = Rng::new(12);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let one_page: usize = {
        let probe = PagedKvManager::new(be.man(), &arch, PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: 0 });
        (0..be.man().cfg.n_layers).map(|l| probe.page_bytes(l)).sum()
    };
    // budget of exactly one page per layer: horizons <= 16 positions serve,
    // anything longer can never fit
    let mut eng = EngineConfig::new().kv_budget_bytes(one_page).build(be.clone(), &store, &arch).unwrap();
    assert!(
        eng.submit(GenRequest::new(vec![1; 8], 16)).is_err(),
        "24-position horizon must be rejected against a 16-position pool"
    );
    assert_eq!(eng.metrics.rejected_prompts, 1);
    eng.submit(GenRequest::new(vec![1; 8], 8)).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.len(), 1, "a horizon that fits the pool must still serve");
}

#[test]
fn schedulers_order_admissions_under_contention() {
    let be = backend();
    let mut rng = Rng::new(13);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    // budget for ~1.5 sequences: admissions serialize, so completion order
    // == admission order == the scheduler's policy order
    let one_seq: usize = {
        let mut probe = PagedKvManager::new(be.man(), &arch, PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 });
        probe.admit(1, 16);
        probe.allocated_bytes()
    };
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    let mut prng = Rng::new(3);
    for len in [12usize, 4, 8, 6] {
        prompts.push(sample_sequence(&world, &mix, len, &mut prng)[..len].to_vec());
    }

    let run = |kind: SchedulerKind, priorities: [i32; 4]| {
        let mut eng = EngineConfig::new()
            .kv_budget_bytes(one_seq + one_seq / 2)
            .scheduler(kind)
            .build(be.clone(), &store, &arch)
            .unwrap();
        for (p, prio) in prompts.iter().zip(priorities) {
            // horizon <= 16 for every request: exactly one page each
            eng.submit(GenRequest::new(p.clone(), 16 - p.len()).with_priority(prio)).unwrap();
        }
        let order: Vec<u64> = eng.run_to_completion().unwrap().iter().map(|r| r.id).collect();
        order
    };

    assert_eq!(run(SchedulerKind::Fifo, [0, 3, 1, 2]), vec![1, 2, 3, 4], "fifo = arrival order");
    assert_eq!(
        run(SchedulerKind::Priority, [0, 3, 1, 2]),
        vec![2, 4, 3, 1],
        "priority must beat arrival order under contention"
    );
    assert_eq!(
        run(SchedulerKind::ShortestPromptFirst, [0, 0, 0, 0]),
        vec![2, 4, 3, 1],
        "spf admits prompts of len 4,6,8,12 in that order"
    );
}

#[test]
fn cancellation_frees_kv_pages_exactly() {
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(14);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    assert_eq!(eng.kv_allocated_bytes(), 0);

    let id1 = eng.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.kv_active_seqs(), 1);
    let after_one = eng.kv_allocated_bytes();
    assert!(after_one > 0);

    let id2 = eng.submit(GenRequest::new(vec![1, y, y], 40)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.kv_active_seqs(), 2, "self-loop store keeps both mid-generation");
    let after_two = eng.kv_allocated_bytes();
    assert!(after_two > after_one);

    // a third request queues behind the full slots; cancelling it never
    // touches the pool
    let id3 = eng.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    assert!(eng.cancel(id3));
    assert_eq!(eng.kv_allocated_bytes(), after_two);

    // cancel mid-generation: exactly the second sequence's pages come back
    assert!(eng.cancel(id2));
    assert_eq!(eng.kv_allocated_bytes(), after_one);
    assert_eq!(eng.kv_active_seqs(), 1);
    assert!(!eng.cancel(id2), "cancelling twice is a no-op");
    assert!(!eng.cancel(9999), "unknown id is a no-op");

    assert!(eng.cancel(id1));
    assert_eq!(eng.kv_allocated_bytes(), 0);
    assert!(eng.is_idle());

    let resp = eng.take_finished();
    assert_eq!(resp.len(), 3);
    assert!(resp.iter().all(|r| r.finish == FinishReason::Cancelled));
    let r1 = resp.iter().find(|r| r.id == id1).unwrap();
    assert!(!r1.tokens.is_empty(), "cancelled mid-generation keeps its partial tokens");
    let r3 = resp.iter().find(|r| r.id == id3).unwrap();
    assert!(r3.tokens.is_empty(), "cancelled while queued never generated");
    assert_eq!(eng.metrics.cancelled, 3);
    assert_eq!(eng.metrics.requests_completed, 0);
}

#[test]
fn seeded_sampling_is_reproducible_and_seed_sensitive() {
    let be = backend();
    let mut rng = Rng::new(15);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(4);
    let prompt = sample_sequence(&world, &mix, 10, &mut prng);

    let run = |seed: u64| {
        let mut eng =
            EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
        let params = SamplingParams::temperature(0.9).with_seed(seed);
        eng.submit(GenRequest::new(prompt.clone(), 12).with_sampling(params)).unwrap();
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let a = run(7);
    assert_eq!(a, run(7), "same seed must reproduce the same tokens");
    assert!(a.iter().all(|&t| t < be.man().cfg.v as u32));
    let differs = [8u64, 9, 10].iter().any(|&s| run(s) != a);
    assert!(differs, "different seeds must eventually produce different tokens");
}

#[test]
fn step_streaming_yields_the_same_tokens_as_run_to_completion() {
    let be = backend();
    let mut rng = Rng::new(16);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let world = World::new(5, be.man().cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let n_req = be.man().cfg.b_decode * 2 + 1;
    let mut prompts = Vec::new();
    let mut prng = Rng::new(6);
    for _ in 0..n_req {
        prompts.push(sample_sequence(&world, &mix, 8, &mut prng));
    }

    let mk = || EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let mut blocking = mk();
    let mut streaming = mk();
    for p in &prompts {
        blocking.submit(GenRequest::new(p.clone(), 6)).unwrap();
        streaming.submit(GenRequest::new(p.clone(), 6)).unwrap();
    }
    let responses = blocking.run_to_completion().unwrap();

    let mut events = Vec::new();
    while !streaming.is_idle() {
        events.extend(streaming.step().unwrap());
    }
    let streamed = streaming.take_finished();

    let mut by_id: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut finishes: HashMap<u64, FinishReason> = HashMap::new();
    for ev in &events {
        match ev {
            StreamEvent::Token { id, tok } => by_id.entry(*id).or_default().push(*tok),
            StreamEvent::Finished { id, reason } => {
                assert!(finishes.insert(*id, *reason).is_none(), "Finished must be terminal per id");
            }
            StreamEvent::Rejected { .. } => panic!("no rejections expected"),
        }
    }
    assert_eq!(streamed.len(), responses.len());
    assert_eq!(finishes.len(), responses.len());
    for r in &responses {
        let s = streamed.iter().find(|x| x.id == r.id).unwrap();
        assert_eq!(s.tokens, r.tokens, "streamed tokens must match the blocking run");
        assert_eq!(s.finish, r.finish);
        assert_eq!(by_id[&r.id], r.tokens, "Token events must carry exactly the generated tokens");
        assert_eq!(finishes[&r.id], r.finish);
    }
}

/// Submit `reqs` to `eng` in order and return each request's tokens, in
/// submission order.
fn run_all(eng: &mut puzzle::serving::Engine, reqs: &[GenRequest]) -> Vec<Vec<u32>> {
    let ids: Vec<u64> = reqs.iter().map(|r| eng.submit(r.clone()).unwrap()).collect();
    let resp = eng.run_to_completion().unwrap();
    ids.iter()
        .map(|id| resp.iter().find(|r| r.id == *id).unwrap().tokens.clone())
        .collect()
}

#[test]
fn prefix_cache_hit_is_byte_identical_to_cold_miss() {
    // the prefix-cache core invariant: generations riding a retained
    // prefix are byte-identical to cold-miss generations — greedy and
    // seeded-stochastic, partial overlaps, chunked prompts, repeats.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(61);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store); // per-layer variable kv heads + a linear layer
    let world = World::new(7, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(5);
    // a shared 24-token system prompt (page_len 16: aligned match = 16+)
    let sys = sample_sequence(&world, &mix, 23, &mut prng);
    assert_eq!(sys.len(), 24);
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for len in [4usize, 6, 2] {
        let mut p = sys.clone();
        p.extend(sample_sequence(&world, &mix, len, &mut prng));
        prompts.push(p);
    }
    // partial-page overlap: shares only 5 tokens with sys -> must miss
    let mut partial = sys[..5].to_vec();
    partial.extend(sample_sequence(&world, &mix, 9, &mut prng));
    prompts.push(partial);
    // chunked prompt: past the 32-token prefill window, sharing sys
    let mut chunked = sys.clone();
    chunked.extend(sample_sequence(&world, &mix, 12, &mut prng));
    assert!(chunked.len() > cfg.s_prefill);
    prompts.push(chunked);
    // exact repeat of the first prompt
    prompts.push(prompts[0].clone());

    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let sampling = if i % 2 == 0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::temperature(0.8).with_seed(40 + i as u64)
            };
            GenRequest::new(p.clone(), 6).with_sampling(sampling)
        })
        .collect();

    let mut cold = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let oracle = run_all(&mut cold, &reqs);
    assert_eq!(cold.metrics.prefix_hits + cold.metrics.prefix_misses, 0, "cache off: no prefix traffic");

    let mut warm = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let got = run_all(&mut warm, &reqs);
    for (i, (g, want)) in got.iter().zip(&oracle).enumerate() {
        assert_eq!(g, want, "request {i}: cache-hit generation must be byte-identical to cold miss");
    }
    assert!(warm.prefix_enabled(), "RefBackend supports kv transfer");
    assert!(warm.metrics.prefix_hits >= 3, "sys-sharing prompts and the repeat must hit");
    assert!(warm.metrics.prefix_tokens_saved >= 3 * 16, "each hit saves >= one page of prefill");
    assert!(warm.metrics.prefix_misses >= 2, "the first prompt and the partial overlap miss");
    // all request pages returned; only retained segments keep bytes
    assert_eq!(warm.kv_allocated_bytes(), warm.prefix_retained_bytes());
    assert!(warm.prefix_segments() > 0);

    // a full-window retention serves >= 32-token hits: chunked prompt
    // cold on a fresh engine, then again
    let mut warm2 = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let chunked_req = vec![GenRequest::new(chunked.clone(), 6)];
    let first = run_all(&mut warm2, &chunked_req);
    let again = run_all(&mut warm2, &chunked_req);
    assert_eq!(first, again, "chunked hit must reproduce the chunked cold run");
    assert_eq!(warm2.metrics.prefix_hits, 1);
    assert_eq!(
        warm2.metrics.prefix_tokens_saved, 32,
        "the full prefill window is retained and reused"
    );
}

#[test]
fn prefix_eviction_respects_live_refs_and_budget() {
    // satellite edge cases: eviction under budget pressure never evicts a
    // segment with live references; once the reference drops the LRU
    // segment goes; a hit on a prefix retained by a *cancelled* request
    // still works; retain -> cancel -> re-admit accounting stays exact.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(62);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    // retain budget for exactly ONE 16-token segment
    let one_seg = {
        let probe = PagedKvManager::new(
            be.man(),
            &arch,
            PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 },
        );
        probe.shared_bytes(16)
    };
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefix_cache(true, one_seg)
        .build(be.clone(), &store, &arch)
        .unwrap();

    let p1: Vec<u32> = std::iter::once(1u32).chain(std::iter::repeat(y).take(16)).collect();
    let mut p2 = p1.clone();
    p2[0] = 3; // diverges at token 0: its own radix path

    // cold run retains S1 (16 tokens of p1)
    eng.submit(GenRequest::new(p1.clone(), 2)).unwrap();
    eng.run_to_completion().unwrap();
    assert_eq!(eng.prefix_segments(), 1);
    let retained = eng.prefix_retained_bytes();
    assert_eq!(retained, one_seg, "page-aligned pool and host bytes agree");

    // B hits S1 and keeps running (self-loop: never finishes on its own)
    let idb = eng.submit(GenRequest::new(p1.clone(), 40)).unwrap();
    eng.step().unwrap();
    assert_eq!(eng.metrics.prefix_hits, 1);

    // C's retention wants the only budget slot, but S1 has a live ref:
    // nothing may be evicted, so retention is skipped — admission never
    // breaks, the segment survives
    eng.submit(GenRequest::new(p2.clone(), 2)).unwrap();
    while eng.queue_len() > 0 || eng.active() > 1 {
        eng.step().unwrap();
    }
    assert_eq!(eng.metrics.prefix_evictions, 0, "a referenced segment must never be evicted");
    assert_eq!(eng.prefix_segments(), 1, "S1 survives the pressure");

    // cancel B: its pages come back, accounting is exactly retained-only
    assert!(eng.cancel(idb));
    assert_eq!(eng.kv_allocated_bytes(), retained, "retain -> cancel accounting must be exact");

    // a hit on the prefix retained via the now-cancelled lineage works
    let idd = eng.submit(GenRequest::new(p1.clone(), 3)).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.iter().find(|r| r.id == idd).unwrap().tokens, vec![y; 3]);
    assert_eq!(eng.metrics.prefix_hits, 2, "cancellation must not invalidate the segment");
    assert_eq!(eng.kv_allocated_bytes(), retained);

    // with the ref gone, C's retention now evicts LRU S1 and takes the slot
    eng.submit(GenRequest::new(p2.clone(), 2)).unwrap();
    eng.run_to_completion().unwrap();
    assert_eq!(eng.metrics.prefix_evictions, 1, "unreferenced LRU segment must be evicted");
    assert_eq!(eng.prefix_segments(), 1, "the retain budget holds exactly one segment");
    // p1 now misses (its segment is gone) but stays byte-identical
    let ide = eng.submit(GenRequest::new(p1.clone(), 3)).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.iter().find(|r| r.id == ide).unwrap().tokens, vec![y; 3]);
    assert_eq!(eng.clear_prefix_cache(), 1);
    assert_eq!(eng.kv_allocated_bytes(), 0, "clearing the cache returns the pool to empty");
}

#[test]
fn finished_sequences_retain_segments_over_generated_tokens() {
    // DESIGN.md §9 retention rule: at finish, the engine retains the
    // committed stream (prompt ++ generated, minus the newest sampled
    // token, page-aligned) — so a multi-turn follow-up whose prompt
    // extends the previous completion hits rows the *decode* path wrote,
    // not just cold-prefill rows.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(63);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(16 << 20)
        .page_len(4)
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)
        .unwrap();

    // turn 1: 7-token prompt, 9 generated (self-loop on y, no EOS).
    // finish retains align_down(7 + 9 - 1, 4) = 12 rows: 7 prompt-origin
    // + 5 generated-origin (gen_from = 7).
    let p1: Vec<u32> = std::iter::once(1u32).chain(std::iter::repeat(y).take(6)).collect();
    eng.submit(GenRequest::new(p1.clone(), 9)).unwrap();
    let r1 = eng.run_to_completion().unwrap().remove(0);
    assert_eq!(r1.tokens, vec![y; 9]);
    assert_eq!(r1.finish, FinishReason::MaxNew);
    assert!(eng.prefix_segments() >= 2, "admit-time chunk AND finish-time stream retained");
    assert_eq!(eng.metrics.prefix_gen_hits, 0, "retention alone is not a hit");

    // turn 2 extends turn 1's full prompt + completion: the hit runs 12
    // tokens deep, 5 of them past the prompt-origin boundary
    let mut p2 = p1.clone();
    p2.extend(&r1.tokens);
    p2.push(y);
    eng.submit(GenRequest::new(p2.clone(), 4)).unwrap();
    let r2 = eng.run_to_completion().unwrap().remove(0);
    assert_eq!(r2.tokens, vec![y; 4], "generation over retained decode rows stays correct");
    assert_eq!(eng.metrics.prefix_hits, 1);
    assert_eq!(eng.metrics.prefix_tokens_saved, 12);
    assert_eq!(eng.metrics.prefix_gen_hits, 1, "the hit crossed into generated-origin rows");
    assert_eq!(eng.metrics.prefix_gen_tokens_saved, 5);
    // the oracle: a cache-off engine generates the same continuation
    let mut cold = EngineConfig::new()
        .kv_budget_bytes(16 << 20)
        .page_len(4)
        .build(be.clone(), &store, &arch)
        .unwrap();
    cold.submit(GenRequest::new(p2, 4)).unwrap();
    assert_eq!(cold.run_to_completion().unwrap()[0].tokens, r2.tokens);
    // pages: everything beyond retained segments was handed back
    assert_eq!(eng.kv_allocated_bytes(), eng.prefix_retained_bytes());
}

#[test]
fn generation_stops_at_eos_through_the_decode_path() {
    // engineer weights so the model deterministically generates
    // token-chain y -> z -> EOS: residual blocks are zeroed (wo = wd = 0),
    // so the hidden state at each position is the token's embedding, and
    // the tied head makes E rows steer the chain.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let (d, v) = (cfg.d, cfg.v);
    let mut rng = Rng::new(9);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);

    // zero the output projections: every block becomes the identity
    for l in 0..cfg.n_layers {
        let wo = store.get(&block_key(l, "attn", "gqa_r1", "wo")).unwrap().clone();
        store.put(&block_key(l, "attn", "gqa_r1", "wo"), puzzle::tensor::Tensor::zeros(&wo.shape));
        let wd = store.get(&block_key(l, "ffn", "r100", "wd")).unwrap().clone();
        store.put(&block_key(l, "ffn", "r100", "wd"), puzzle::tensor::Tensor::zeros(&wd.shape));
    }
    // craft the embedding: rows are near-zero noise except the chain rows
    let (y, z) = (10u32, 11u32);
    let mut e = puzzle::tensor::Tensor::zeros(&[v, d]);
    for x in e.data.iter_mut() {
        *x = rng.normal() * 1e-3;
    }
    let row = |t: u32| (t as usize) * d;
    e.data[row(y)..row(y) + d].fill(0.0);
    e.data[row(y)] = 1.0; // E[y] = e1
    e.data[row(z)..row(z) + d].fill(0.0);
    e.data[row(z)] = 2.0; // E[z] = 2*e1 + e2: from y, z scores highest
    e.data[row(z) + 1] = 1.0;
    e.data[row(EOS)..row(EOS) + d].fill(0.0);
    e.data[row(EOS) + 1] = 6.0; // from z, EOS scores highest
    store.put("embed", e);

    let mut eng = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    eng.submit(GenRequest::new(vec![1, y], 10)).unwrap();
    let resp = eng.run_to_completion().unwrap();
    assert_eq!(resp.len(), 1);
    assert_eq!(
        resp[0].tokens,
        vec![z, EOS],
        "must generate z from prefill, then EOS through a decode step, then stop"
    );
    assert_eq!(resp[0].finish, FinishReason::Eos);
    assert_eq!(eng.metrics.generated_tokens, 2);
    assert_eq!(eng.metrics.finished_eos, 1);
    assert!(eng.metrics.decode_steps >= 1, "EOS must be produced by the decode path");
}

#[test]
fn budgeted_prefill_is_byte_identical_across_budgets_and_caches() {
    // the chunked-prefill core invariant (DESIGN.md §10): outputs are a
    // pure function of (weights, prompt, sampling), never of the budget
    // or of what shares the batch — so every budget, with and without
    // the prefix cache, must reproduce the inline-prefill oracle exactly.
    // Greedy and seeded-stochastic sampling, shared prefixes, a chunked
    // (over-window) prompt, and an exact repeat, over a child arch with
    // per-layer variable KV heads.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(71);
    let mut store = init_parent(be.man(), &mut rng);
    let arch = variable_arch(&*be, &mut store);
    let world = World::new(7, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut prng = Rng::new(8);
    let sys = sample_sequence(&world, &mix, 23, &mut prng); // shared 24-token prefix
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for len in [4usize, 6] {
        let mut p = sys.clone();
        p.extend(sample_sequence(&world, &mix, len, &mut prng));
        prompts.push(p);
    }
    prompts.push(sample_sequence(&world, &mix, 5, &mut prng)); // cold outlier
    let mut long = sys.clone();
    long.extend(sample_sequence(&world, &mix, 12, &mut prng));
    assert!(long.len() > cfg.s_prefill, "one prompt must cross the prefill window");
    prompts.push(long);
    prompts.push(prompts[0].clone()); // repeat: budgeted retention must serve it

    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let sampling = if i % 2 == 0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::temperature(0.8).with_seed(50 + i as u64)
            };
            GenRequest::new(p.clone(), 6).with_sampling(sampling)
        })
        .collect();

    let build = |budget: Option<usize>, cache: bool| {
        let mut ec = EngineConfig::new().kv_budget_bytes(32 << 20);
        if let Some(b) = budget {
            ec = ec.prefill_budget(b);
        }
        if cache {
            ec = ec.prefix_cache(true, 8 << 20);
        }
        ec.build(be.clone(), &store, &arch).unwrap()
    };
    let mut oracle_eng = build(None, false);
    let oracle = run_all(&mut oracle_eng, &reqs);

    for budget in [1usize, 3, 16, 64] {
        for cache in [false, true] {
            let mut eng = build(Some(budget), cache);
            let got = run_all(&mut eng, &reqs);
            assert_eq!(
                got, oracle,
                "budget {budget} cache {cache}: chunked outputs must be byte-identical"
            );
            assert!(
                eng.metrics.prefill_chunk_passes > 0,
                "budget {budget}: the budget path must have run"
            );
            assert_eq!(
                eng.metrics.prefills, 0,
                "a budgeted engine never runs an inline prefill pass"
            );
            if cache {
                assert!(
                    eng.metrics.prefix_hits > 0,
                    "budget {budget}: full-ingest retention must produce hits"
                );
            }
        }
    }
}

#[test]
fn budgeted_admission_bounds_head_of_line_delay() {
    // the head-of-line regression: a near-horizon prompt admitted while a
    // lane is mid-decode adds at most `prefill_budget` tokens of
    // ingestion work per step — the live lane emits a token on EVERY
    // step, never stalling for the monster's prefill.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let y = 10u32;
    let mut rng = Rng::new(72);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let budget = 4usize;
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefill_budget(budget)
        .build(be.clone(), &store, &arch)
        .unwrap();

    let ida = eng.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    let mut a_tokens = 0usize;
    for _ in 0..3 {
        for ev in eng.step().unwrap() {
            if let StreamEvent::Token { id, .. } = ev {
                assert_eq!(id, ida);
                a_tokens += 1;
            }
        }
    }
    assert_eq!(a_tokens, 3, "the live lane decodes one token per step");

    // a near-horizon prompt lands mid-decode; admission books pages only
    let monster: Vec<u32> =
        std::iter::once(1u32).chain(std::iter::repeat(y)).take(cfg.s_max - 4).collect();
    let idm = eng.submit(GenRequest::new(monster.clone(), 2)).unwrap();
    let need = monster.len() - 1; // pending tokens the chunk passes + TF steps ingest
    let mut ingested = eng.metrics.prefill_chunk_tokens;
    let mut m_first = None;
    let mut steps = 0usize;
    while m_first.is_none() {
        steps += 1;
        assert!(steps <= need, "the monster's first token must arrive within bounded steps");
        let evs = eng.step().unwrap();
        let delta = eng.metrics.prefill_chunk_tokens - ingested;
        ingested = eng.metrics.prefill_chunk_tokens;
        assert!(
            delta <= budget,
            "step ingested {delta} chunk tokens — the per-step budget bound is {budget}"
        );
        let a_now = evs
            .iter()
            .filter(|e| matches!(e, StreamEvent::Token { id, .. } if *id == ida))
            .count();
        assert_eq!(
            a_now, 1,
            "the live lane must emit exactly one token EVERY step — a monster admission may \
             add at most one budget of work, never an inline-prefill stall"
        );
        if evs.iter().any(|e| matches!(e, StreamEvent::Token { id, .. } if *id == idm)) {
            m_first = Some(steps);
        }
    }
    // ingestion drains at (budget + 1 teacher-forced token) per step
    let bound = need.div_ceil(budget + 1) + 2;
    assert!(
        m_first.unwrap() <= bound,
        "monster TTFT {} steps exceeds the drain bound {bound}",
        m_first.unwrap()
    );
}

#[test]
fn budgeted_cancellation_frees_pages_exactly_mid_ingest() {
    // engine-level twin of the async-handle cancellation test: cancelling
    // a request whose chunked ingestion is still in flight returns
    // exactly its full-horizon page booking, retains no partial prefix
    // segment, and leaves the live lane untouched.
    let be = backend();
    let cfg = be.man().cfg.clone();
    let y = 10u32;
    let mut rng = Rng::new(73);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefill_budget(3)
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)
        .unwrap();
    assert_eq!(eng.kv_allocated_bytes(), 0);

    let ida = eng.submit(GenRequest::new(vec![1, y], 40)).unwrap();
    eng.step().unwrap();
    let after_a = eng.kv_allocated_bytes();
    assert!(after_a > 0);

    let monster: Vec<u32> =
        std::iter::once(1u32).chain(std::iter::repeat(y)).take(cfg.s_max - 4).collect();
    let need = monster.len() - 1;
    let idm = eng.submit(GenRequest::new(monster, 2)).unwrap();
    for _ in 0..3 {
        eng.step().unwrap();
    }
    // horizons are booked at admit, so per-sequence bytes are constant
    let mid = eng.kv_allocated_bytes();
    assert!(mid > after_a, "the monster's horizon is booked up front");
    assert!(
        eng.metrics.prefill_chunk_tokens < need,
        "the cancel must land while ingestion is still in flight"
    );

    assert!(eng.cancel(idm));
    assert_eq!(
        eng.kv_allocated_bytes(),
        after_a,
        "cancel mid-ingest must free exactly the monster's booking"
    );
    assert_eq!(eng.prefix_segments(), 0, "no partial-prefix segment may be retained");

    // the live lane runs to its natural finish, byte-exact
    let resp = eng.run_to_completion().unwrap();
    let ra = resp.iter().find(|r| r.id == ida).unwrap();
    assert_eq!(ra.tokens, vec![y; 40]);
    assert_eq!(ra.finish, FinishReason::MaxNew);
    let rm = resp.iter().find(|r| r.id == idm).unwrap();
    assert!(rm.tokens.is_empty(), "cancelled mid-prefill: no token was ever sampled");
    assert_eq!(rm.finish, FinishReason::Cancelled);
    // only A's finish-time retention keeps bytes now
    assert_eq!(eng.kv_allocated_bytes(), eng.prefix_retained_bytes());
}

#[test]
fn budgeted_prefill_composes_with_external_spec_sequences() {
    // SpecBatch composition: a budgeted engine serves chunk passes and an
    // externally driven speculative sequence at once; per-lane isolation
    // means the spec logits and the batched tokens both stay bitwise
    // equal to isolated runs.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(74);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    let spec_prompt = vec![1u32, 5, 9];
    let probe = [7u32, 11, 13];
    let batch_prompt: Vec<u32> =
        std::iter::once(1u32).chain(std::iter::repeat(y).take(11)).collect();

    // isolated oracles on a budget-free engine
    let mut eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch).unwrap();
    let (sid, first_iso) = eng.spec_open(&spec_prompt).unwrap();
    let rows_iso = eng.spec_extend(sid, &probe, 0).unwrap();
    eng.spec_close(sid);
    eng.submit(GenRequest::new(batch_prompt.clone(), 6)).unwrap();
    let tokens_iso = eng.run_to_completion().unwrap().remove(0).tokens;
    assert_eq!(tokens_iso, vec![y; 6]);

    // mixed: the spec sequence stays open while the batched prompt
    // ingests 3 tokens per step right alongside it
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .prefill_budget(3)
        .build(be.clone(), &store, &arch)
        .unwrap();
    let (sid, first_mix) = eng.spec_open(&spec_prompt).unwrap();
    assert_eq!(first_mix, first_iso, "spec prefill must not see the budgeted lane");
    eng.submit(GenRequest::new(batch_prompt, 6)).unwrap();
    eng.step().unwrap(); // admission books pages; the first chunk pass runs
    let mut rows_mix = eng.spec_extend(sid, &probe[..1], 0).unwrap();
    eng.step().unwrap();
    rows_mix.extend(eng.spec_extend(sid, &probe[1..], 0).unwrap());
    while !eng.is_idle() {
        eng.step().unwrap();
    }
    let resp = eng.take_finished();
    assert_eq!(resp[0].tokens, tokens_iso, "budgeted ingestion must ignore the spec lane");
    assert_eq!(rows_mix, rows_iso, "spec logits must ignore interleaved chunk passes");
    assert!(eng.metrics.prefill_chunk_passes > 0, "the budget path must have run");
    eng.spec_close(sid);
    assert_eq!(eng.kv_allocated_bytes(), 0, "closing the spec lane returns the pool to empty");
}

#[test]
fn spf_aging_admits_a_long_prompt_under_short_pressure() {
    // engine-level starvation regression for the scheduler aging fix:
    // without the `waited` term, ShortestPromptFirst would admit every
    // short prompt before the long one — the long prompt finishes LAST,
    // deterministically. With aging, each queued step discounts its
    // effective length, so it overtakes the tail of the short stream.
    let be = backend();
    let y = 10u32;
    let mut rng = Rng::new(75);
    let store = self_loop_store(&*be, y, &mut rng);
    let arch = Arch::parent(be.man().cfg.n_layers);
    // budget for ~1.5 sequences: admissions serialize (same trick as
    // schedulers_order_admissions_under_contention)
    let one_seq: usize = {
        let mut probe = PagedKvManager::new(
            be.man(),
            &arch,
            PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: usize::MAX / 2 },
        );
        probe.admit(1, 16);
        probe.allocated_bytes()
    };
    let mut eng = EngineConfig::new()
        .kv_budget_bytes(one_seq + one_seq / 2)
        .scheduler(SchedulerKind::ShortestPromptFirst)
        .build(be.clone(), &store, &arch)
        .unwrap();

    // the long prompt arrives FIRST, then a stream of shorts; self-loop
    // generation (no EOS) makes every completion run its full max_new, so
    // the admission timeline is deterministic. Horizons are all 16 (one
    // page): long 12+4, shorts 4+12.
    let mut long = vec![2u32; 11];
    long.push(y);
    let long_id = eng.submit(GenRequest::new(long, 4)).unwrap();
    for _ in 0..4 {
        eng.submit(GenRequest::new(vec![3u32, 4, 5, y], 12)).unwrap();
    }
    let order: Vec<u64> = eng.run_to_completion().unwrap().iter().map(|r| r.id).collect();
    assert_eq!(order.len(), 5);
    let pos = order.iter().position(|&id| id == long_id).unwrap();
    assert_ne!(pos, 0, "a fresh short still beats the long prompt at waited = 0");
    assert!(
        pos < order.len() - 1,
        "aging must admit the long prompt before the short stream drains; without the \
         waited term it would deterministically finish last (order: {order:?})"
    );
}
