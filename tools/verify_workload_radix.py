#!/usr/bin/env python3
"""Toolchain-free cross-check of the PR-6 prefix-cache extensions
(rust/src/serving/prefixcache.rs): the radix tree's generated-origin
bookkeeping (`gen_from` on insert, `PrefixHit.gen_tokens` on lookup) and
the engine's finish-time retention arithmetic (engine.rs `maybe_retain` /
`finish`).

1. A line-for-line python transcription of the radix tree (insert with
   edge splitting, best_match with frontier descent, covered, remove with
   upward pruning, LRU order) is fuzzed against a naive
   `[(id, path, gen_from)]` model: hit length must equal the brute-force
   page-aligned longest-common-prefix bound, the chosen segment must
   really share the matched tokens, gen_tokens must be the segment's
   generated-origin share of the match, and covered/segments/bytes stay
   exact across random insert/lookup/evict interleavings.
2. The finish-time retention rule — rows ingested = prompt + generated
   - 1 (the newest sampled token has no K/V row), retain_len =
   align_down(min(ingested, stream)), gen_from = min(prompt_len,
   retain_len) — is checked against the tree over random
   (prompt, completion) pairs: a follow-up prompt extending the full
   stream hits exactly align_down(min(lcp, follow_len - 1)) tokens and
   credits exactly max(0, hit - prompt_len) generated-origin rows.
3. The concrete anchor from tests/serving_integration.rs
   (`finished_sequences_retain_segments_over_generated_tokens`): prompt
   7, 9 generated, page 4 -> retained 12 with gen_from 7; the 17-token
   follow-up hits 12 and saves 5 generated-origin rows.

Run: python3 tools/verify_workload_radix.py
"""

import random
import sys


def align_down(n, page):
    return (n // page) * page


def lcp(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class Node:
    __slots__ = ("edge", "children", "seg", "depth", "parent")

    def __init__(self, edge, children, seg, depth, parent):
        self.edge, self.children, self.seg, self.depth, self.parent = (
            edge, children, seg, depth, parent)


class PrefixCache:
    """Transcription of prefixcache.rs::PrefixCache (bookkeeping only;
    KvSegment rows are reduced to a byte count)."""

    def __init__(self, page_len, seg_bytes):
        self.nodes = [Node([], [], None, 0, 0)]
        self.segs = {}  # id -> (node, last_use, gen_from, bytes, len)
        self.next_seg = 1
        self.clock = 0
        self.page_len = page_len
        self.seg_bytes = seg_bytes  # len -> host bytes
        self.retained_bytes = 0

    def segments(self):
        return len(self.segs)

    def best_match(self, prompt):
        cur, i = 0, 0
        deepest = None
        frontier = None
        while True:
            node = self.nodes[cur]
            if node.seg is not None and node.depth > 0:
                deepest = (node.seg, node.depth)
            if i >= len(prompt):
                frontier = node.children[0] if node.children else None
                break
            child = next((c for c in node.children
                          if self.nodes[c].edge[0] == prompt[i]), None)
            if child is None:
                frontier = node.children[0] if node.children else None
                break
            edge = self.nodes[child].edge
            common = lcp(edge, prompt[i:])
            i += common
            if common == len(edge):
                cur = child
                continue
            frontier = child
            break
        m = align_down(min(i, len(prompt) - 1), self.page_len)
        if m == 0:
            return None
        if frontier is not None:
            n = frontier
            while True:
                if self.nodes[n].seg is not None:
                    return (self.nodes[n].seg, m)
                if not self.nodes[n].children:
                    break
                n = self.nodes[n].children[0]
        if deepest is None:
            return None
        seg, depth = deepest
        return (seg, min(depth, m))

    def lookup(self, prompt):
        if len(prompt) <= 1:
            return None
        hit = self.best_match(prompt)
        if hit is None:
            return None
        seg_id, length = hit
        self.clock += 1
        node, _, gen_from, nbytes, slen = self.segs[seg_id]
        self.segs[seg_id] = (node, self.clock, gen_from, nbytes, slen)
        return (seg_id, length, max(0, length - gen_from))

    def covered(self, tokens, length):
        cur, i = 0, 0
        while i < length:
            child = next((c for c in self.nodes[cur].children
                          if self.nodes[c].edge[0] == tokens[i]), None)
            if child is None:
                return False
            edge = self.nodes[child].edge
            common = lcp(edge, tokens[i:length])
            i += common
            if common < len(edge):
                return i == length
            cur = child
        return True

    def insert(self, tokens, seg_len, gen_from):
        assert 0 < seg_len <= len(tokens)
        assert seg_len % self.page_len == 0
        assert gen_from <= seg_len
        node = self.insert_path(tokens[:seg_len])
        assert self.nodes[node].seg is None
        sid = self.next_seg
        self.next_seg += 1
        self.nodes[node].seg = sid
        self.clock += 1
        nbytes = self.seg_bytes(seg_len)
        self.retained_bytes += nbytes
        self.segs[sid] = (node, self.clock, gen_from, nbytes, seg_len)
        return sid

    def insert_path(self, tokens):
        cur, i = 0, 0
        while i < len(tokens):
            child = next((c for c in self.nodes[cur].children
                          if self.nodes[c].edge[0] == tokens[i]), None)
            if child is None:
                idx = len(self.nodes)
                self.nodes.append(
                    Node(list(tokens[i:]), [], None, len(tokens), cur))
                self.nodes[cur].children.append(idx)
                return idx
            edge = list(self.nodes[child].edge)
            common = lcp(edge, tokens[i:])
            if common == len(edge):
                cur = child
                i += common
                continue
            mid = len(self.nodes)
            self.nodes.append(Node(edge[:common], [child], None,
                                   self.nodes[cur].depth + common, cur))
            pos = self.nodes[cur].children.index(child)
            self.nodes[cur].children[pos] = mid
            self.nodes[child].edge = edge[common:]
            self.nodes[child].parent = mid
            if i + common == len(tokens):
                return mid
            leaf = len(self.nodes)
            self.nodes.append(
                Node(list(tokens[i + common:]), [], None, len(tokens), mid))
            self.nodes[mid].children.append(leaf)
            return leaf
        return cur

    def remove(self, seg_id):
        if seg_id not in self.segs:
            return False
        node, _, _, nbytes, _ = self.segs.pop(seg_id)
        self.retained_bytes -= nbytes
        cur = node
        self.nodes[cur].seg = None
        while (cur != 0 and self.nodes[cur].seg is None
               and not self.nodes[cur].children):
            parent = self.nodes[cur].parent
            self.nodes[parent].children.remove(cur)
            cur = parent
        return True


def seg_bytes(length):
    # mirrors the rust unit-test fixture: one caching layer, 4-float
    # rows, k+v, 4 bytes per f32
    return 2 * (length * 4) * 4


def fuzz_tree(seed, rounds=400, page=2):
    rng = random.Random(seed)
    c = PrefixCache(page, seg_bytes)
    model = []  # (id, path, gen_from)

    def gen_path():
        length = page * rng.randrange(1, 7)
        p = []
        if model and rng.randrange(2) == 0:
            base = model[rng.randrange(len(model))][1]
            keep = rng.randrange(min(len(base), length) + 1)
            p = list(base[:keep])
        while len(p) < length:
            p.append(rng.randrange(4))
        return p

    for _ in range(rounds):
        op = rng.randrange(10)
        if op <= 3:
            path = gen_path()
            model_covered = any(lcp(p, path) >= len(path) for _, p, _ in model)
            assert c.covered(path, len(path)) == model_covered
            if not model_covered:
                gen_from = rng.randrange(len(path) + 1)
                sid = c.insert(path, len(path), gen_from)
                model.append((sid, path, gen_from))
        elif op <= 7:
            q = gen_path()
            if rng.randrange(4) == 0 and q:
                q[rng.randrange(len(q))] = 7
            for _ in range(rng.randrange(3)):
                q.append(rng.randrange(4))
            if len(q) <= 1:
                expect = 0
            else:
                best = max((lcp(p, q) for _, p, _ in model), default=0)
                expect = align_down(min(best, len(q) - 1), page)
            hit = c.lookup(q)
            if hit is None:
                assert expect == 0, (q, expect)
            else:
                sid, hlen, gen_tokens = hit
                assert hlen == expect, (q, hlen, expect)
                _, path, gen_from = next(m for m in model if m[0] == sid)
                assert lcp(path, q) >= hlen
                assert gen_tokens == max(0, hlen - gen_from)
        elif op == 8:
            if model and rng.randrange(4) != 0:
                sid, _, _ = model.pop(rng.randrange(len(model)))
                assert c.remove(sid)
                assert not c.remove(sid)
            else:
                assert not c.remove(1 << 60)
        else:
            q = gen_path()
            ln = rng.randrange(len(q) + 1)
            model_covered = ln == 0 or any(
                lcp(p, q) >= ln for _, p, _ in model)
            assert c.covered(q, ln) == model_covered
        assert c.segments() == len(model)
        assert c.retained_bytes == sum(
            seg_bytes(len(p)) for _, p, _ in model)


def fuzz_retention_rule(seed, rounds=300):
    """Engine finish-time retention (engine.rs finish -> maybe_retain)
    against the tree: retain the committed stream capped at ingested
    rows, then check a follow-up prompt's hit and gen-credit exactly."""
    rng = random.Random(seed)
    for _ in range(rounds):
        page = rng.choice([2, 4, 8])
        c = PrefixCache(page, seg_bytes)
        prompt = [rng.randrange(1, 50) for _ in range(rng.randrange(2, 20))]
        gen = [rng.randrange(1, 50) for _ in range(rng.randrange(1, 16))]
        stream = prompt + gen
        # rows ingested by finish: prompt + generated - 1 (the newest
        # sampled token was never fed, so it has no K/V row)
        ingested = len(prompt) + len(gen) - 1
        retain_len = align_down(min(ingested, len(stream)), page)
        if retain_len == 0:
            continue
        gen_from = min(len(prompt), retain_len)
        c.insert(stream, retain_len, gen_from)
        # turn N+1: full stream plus fresh user tokens
        follow = stream + [rng.randrange(50, 60) for _ in range(rng.randrange(1, 6))]
        hit = c.lookup(follow)
        expect = align_down(min(retain_len, len(follow) - 1), page)
        assert expect == retain_len  # follow extends the whole path
        assert hit is not None and hit[1] == retain_len
        assert hit[2] == max(0, retain_len - len(prompt))
        # a prompt diverging inside the completion still gets the
        # aligned shared part, credited correctly
        cut = rng.randrange(len(prompt), len(stream))
        div = stream[:cut] + [99, 99]
        hit = c.lookup(div)
        share = align_down(min(cut, retain_len, len(div) - 1), page)
        if share == 0:
            assert hit is None
        else:
            assert hit is not None and hit[1] == share
            assert hit[2] == max(0, share - len(prompt))


def anchor_integration_case():
    """tests/serving_integration.rs::finished_sequences_retain_segments_
    over_generated_tokens, exactly."""
    page = 4
    c = PrefixCache(page, seg_bytes)
    y = 10
    p1 = [1] + [y] * 6          # 7-token prompt
    r1 = [y] * 9                # 9 generated (MaxNew)
    stream = p1 + r1
    ingested = len(p1) + len(r1) - 1          # 15 rows
    retain_len = align_down(min(ingested, len(stream)), page)
    assert retain_len == 12
    gen_from = min(len(p1), retain_len)
    assert gen_from == 7
    c.insert(stream, retain_len, gen_from)
    p2 = p1 + r1 + [y]                         # 17-token follow-up
    hit = c.lookup(p2)
    assert hit is not None
    _, hlen, gen_tokens = hit
    assert hlen == 12, f"prefix_tokens_saved must be 12, got {hlen}"
    assert gen_tokens == 5, f"gen_tokens_saved must be 5, got {gen_tokens}"


def main():
    for seed in range(6):
        fuzz_tree(seed)
    print("1. radix tree (insert/split/lookup/evict + gen_from) == "
          "naive model over 6 fuzz seeds ✓")
    for seed in range(4):
        fuzz_retention_rule(seed)
    print("2. finish-time retention rule (ingested rows, alignment, "
          "gen_from clamp, follow-up credit) exact under fuzz ✓")
    anchor_integration_case()
    print("3. serving_integration.rs multi-turn anchor: retain 12 rows, "
          "gen_from 7, follow-up saves 12 (5 generated-origin) ✓")
    print("all workload-radix cross-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
