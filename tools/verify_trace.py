#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file produced by `--trace-out`.

Checks (stdlib only, no Perfetto dependency):

  1. Document shape: a JSON object with a `traceEvents` array; every event
     carries `name` / `ph` / `ts` / `pid` / `tid`, `ph` is one of M/X/i,
     and every `X` (complete) event has a numeric `dur >= 0`.
  2. Per-track timestamps: within each `tid`, non-metadata events appear
     in non-decreasing `ts` order (the exporter sorts each track).
  3. Request lifecycle: each request track (tid >= 1000) holds exactly one
     enclosing `request` span; its `queued` / `prefill` / `decode` children
     nest inside it, chain end-to-start, and tile its duration exactly.
     Every request that reached a natural finish (a non-cancelled `reason`
     in its args) must carry all three stages — i.e. >= 3 lifecycle stages
     beyond the enclosing span — and at least one such complete lifecycle
     must exist in the file.
  4. Optional config markers: `--expect-spec` requires at least one
     `spec_round` lane instant (speculative serving ran), and
     `--expect-prefix-hit` requires at least one request admitted with
     `hit: true` (the prefix cache matched).

Exit status 0 with a one-line summary on success, 1 with a diagnostic on
the first failure.
"""

import argparse
import json
import sys

LIFECYCLE = ("queued", "prefill", "decode")
TID_REQ_BASE = 1000


def fail(msg):
    print(f"verify_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail("document must be an object with a traceEvents array")
    return doc


def check_shape(events):
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"traceEvents[{i}] is not an object")
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                fail(f"traceEvents[{i}] ({e.get('name', '?')}) missing key {k!r}")
        if e["ph"] not in ("M", "X", "i"):
            fail(f"traceEvents[{i}] has unsupported phase {e['ph']!r}")
        if not isinstance(e["ts"], (int, float)):
            fail(f"traceEvents[{i}] ts is not numeric")
        if e["ph"] == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"traceEvents[{i}] ({e['name']}) X event needs dur >= 0, got {dur!r}")


def check_monotonic(events):
    last = {}
    for i, e in enumerate(events):
        if e["ph"] == "M":
            continue
        tid = e["tid"]
        if tid in last and e["ts"] < last[tid]:
            fail(
                f"traceEvents[{i}] ({e['name']}) ts {e['ts']} goes backwards "
                f"on tid {tid} (previous {last[tid]})"
            )
        last[tid] = e["ts"]


def check_requests(events):
    """Validate span nesting and lifecycle tiling on every request track."""
    tracks = {}
    for e in events:
        if e["ph"] == "X" and e["tid"] >= TID_REQ_BASE:
            tracks.setdefault(e["tid"], []).append(e)
    complete = 0
    hits = 0
    for tid, spans in sorted(tracks.items()):
        reqs = [s for s in spans if s["name"] == "request"]
        if len(reqs) != 1:
            fail(f"tid {tid}: expected exactly one enclosing request span, got {len(reqs)}")
        req = reqs[0]
        r0, r1 = req["ts"], req["ts"] + req["dur"]
        args = req.get("args", {})
        if args.get("hit") is True:
            hits += 1
        stages = {s["name"]: s for s in spans if s["name"] in LIFECYCLE}
        for name, s in stages.items():
            s0, s1 = s["ts"], s["ts"] + s["dur"]
            if s0 < r0 or s1 > r1:
                fail(f"tid {tid}: {name} span [{s0}, {s1}] escapes request [{r0}, {r1}]")
        if len(stages) == len(LIFECYCLE):
            # a full lifecycle must chain end-to-start and tile the request
            if stages["queued"]["ts"] != r0:
                fail(f"tid {tid}: queued must start at the request span")
            cursor = r0
            for name in LIFECYCLE:
                s = stages[name]
                if s["ts"] != cursor:
                    fail(f"tid {tid}: {name} starts at {s['ts']}, expected {cursor}")
                cursor = s["ts"] + s["dur"]
            if cursor != r1:
                fail(f"tid {tid}: lifecycle tiles to {cursor}, request ends at {r1}")
            complete += 1
        else:
            reason = args.get("reason")
            if reason is not None and reason != "cancelled":
                fail(
                    f"tid {tid}: finished request (reason={reason!r}) has only "
                    f"{len(stages) + 1} lifecycle stages: {sorted(stages)}"
                )
    if tracks and complete == 0:
        fail("no request track carries a complete queued/prefill/decode lifecycle")
    return len(tracks), complete, hits


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file (--trace-out output)")
    ap.add_argument(
        "--expect-spec",
        action="store_true",
        help="require at least one spec_round event (speculative serving)",
    )
    ap.add_argument(
        "--expect-prefix-hit",
        action="store_true",
        help="require at least one request admitted with a prefix-cache hit",
    )
    opts = ap.parse_args()

    doc = load(opts.trace)
    events = doc["traceEvents"]
    if not events:
        fail("traceEvents is empty")
    check_shape(events)
    check_monotonic(events)
    n_req, n_complete, n_hits = check_requests(events)
    if n_req == 0:
        fail("no request tracks (tid >= 1000) in the trace")

    n_steps = sum(1 for e in events if e["name"] == "step")
    n_spec = sum(1 for e in events if e["name"] == "spec_round")
    if n_steps == 0 and n_spec == 0:
        fail("neither engine steps nor speculative rounds were recorded")
    if opts.expect_spec and n_spec == 0:
        fail("--expect-spec: no spec_round events in the trace")
    if opts.expect_prefix_hit and n_hits == 0:
        fail("--expect-prefix-hit: no request was admitted with a prefix-cache hit")

    print(
        f"verify_trace: ok: {len(events)} events, {n_req} requests "
        f"({n_complete} complete lifecycles, {n_hits} prefix hits), "
        f"{n_steps} steps, {n_spec} spec rounds"
    )


if __name__ == "__main__":
    main()
