#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file produced by `--trace-out`.

Checks (stdlib only, no Perfetto dependency):

  1. Document shape: a JSON object with a `traceEvents` array; every event
     carries `name` / `ph` / `ts` / `pid` / `tid`, `ph` is one of M/X/i,
     and every `X` (complete) event has a numeric `dur >= 0`.
  2. Per-track timestamps: within each `(pid, tid)` track, non-metadata
     events appear in non-decreasing `ts` order (the exporter sorts each
     track).
  3. Request lifecycle: each request track (tid >= 1000) holds exactly one
     enclosing `request` span; its lifecycle children nest inside it,
     chain end-to-start, and tile its duration exactly. Single-engine
     tracks carry `queued / prefill / decode`; the router's stitched
     tracks (`--fleet`, pid 0) carry `placement / queued / prefill /
     decode`. Every request that reached a natural finish (a
     non-cancelled `reason` in its args) must carry every stage, and at
     least one complete lifecycle must exist in the file.
  4. Fleet structure (`--fleet`): pid 0 is named `puzzle-router` and pid
     r+1 `puzzle-replica-<r>`; at least one `routed` instant exists on
     the router's routing track; every stitched pid-0 request resolves
     cross-process — its `replica` arg names a live replica pid that
     carries the same request id on its own track, and the id's high
     bits encode that replica; every `migration` span is a paired
     begin/end (no `migration_unpaired` markers).
  5. Optional config markers: `--expect-spec` requires at least one
     `spec_round` lane instant (speculative serving ran),
     `--expect-prefix-hit` requires at least one request admitted with
     `hit: true`, and `--expect-migration` (fleet) requires at least one
     adopted migration span.

Exit status 0 with a one-line summary on success, 1 with a diagnostic on
the first failure.
"""

import argparse
import json
import sys

LIFECYCLE = ("queued", "prefill", "decode")
FLEET_LIFECYCLE = ("placement", "queued", "prefill", "decode")
TID_REQ_BASE = 1000
REPLICA_SHIFT = 48


def fail(msg):
    print(f"verify_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail("document must be an object with a traceEvents array")
    return doc


def check_shape(events):
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"traceEvents[{i}] is not an object")
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                fail(f"traceEvents[{i}] ({e.get('name', '?')}) missing key {k!r}")
        if e["ph"] not in ("M", "X", "i"):
            fail(f"traceEvents[{i}] has unsupported phase {e['ph']!r}")
        if not isinstance(e["ts"], (int, float)):
            fail(f"traceEvents[{i}] ts is not numeric")
        if e["ph"] == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"traceEvents[{i}] ({e['name']}) X event needs dur >= 0, got {dur!r}")


def check_monotonic(events):
    last = {}
    for i, e in enumerate(events):
        if e["ph"] == "M":
            continue
        track = (e["pid"], e["tid"])
        if track in last and e["ts"] < last[track]:
            fail(
                f"traceEvents[{i}] ({e['name']}) ts {e['ts']} goes backwards "
                f"on pid {track[0]} tid {track[1]} (previous {last[track]})"
            )
        last[track] = e["ts"]


def check_requests(events, lifecycle_for_pid):
    """Validate span nesting and lifecycle tiling on every request track.

    `lifecycle_for_pid(pid)` names the stage chain that pid's request
    tracks must tile with (the router's stitched tracks lead with a
    `placement` stage the replica-local view cannot see).
    """
    tracks = {}
    for e in events:
        if e["ph"] == "X" and e["tid"] >= TID_REQ_BASE:
            tracks.setdefault((e["pid"], e["tid"]), []).append(e)
    complete = 0
    hits = 0
    for (pid, tid), spans in sorted(tracks.items()):
        lifecycle = lifecycle_for_pid(pid)
        reqs = [s for s in spans if s["name"] == "request"]
        if len(reqs) != 1:
            fail(
                f"pid {pid} tid {tid}: expected exactly one enclosing request span, "
                f"got {len(reqs)}"
            )
        req = reqs[0]
        r0, r1 = req["ts"], req["ts"] + req["dur"]
        args = req.get("args", {})
        if args.get("hit") is True:
            hits += 1
        stages = {s["name"]: s for s in spans if s["name"] in lifecycle}
        for name, s in stages.items():
            s0, s1 = s["ts"], s["ts"] + s["dur"]
            if s0 < r0 or s1 > r1:
                fail(f"pid {pid} tid {tid}: {name} span [{s0}, {s1}] escapes request [{r0}, {r1}]")
        if len(stages) == len(lifecycle):
            # a full lifecycle must chain end-to-start and tile the request
            cursor = r0
            for name in lifecycle:
                s = stages[name]
                if s["ts"] != cursor:
                    fail(f"pid {pid} tid {tid}: {name} starts at {s['ts']}, expected {cursor}")
                cursor = s["ts"] + s["dur"]
            if cursor != r1:
                fail(f"pid {pid} tid {tid}: lifecycle tiles to {cursor}, request ends at {r1}")
            complete += 1
        else:
            reason = args.get("reason")
            if reason is not None and reason != "cancelled":
                fail(
                    f"pid {pid} tid {tid}: finished request (reason={reason!r}) has only "
                    f"{len(stages) + 1} lifecycle stages: {sorted(stages)}"
                )
    if tracks and complete == 0:
        fail("no request track carries a complete lifecycle")
    return len(tracks), complete, hits


def check_fleet(events):
    """Fleet-merge structure: pid naming, cross-pid request stitching, and
    migration span pairing. Returns (replicas, routed, migrations)."""
    # 1. Process naming: pid 0 is the router, pid r+1 replica r.
    names = {
        e["pid"]: e.get("args", {}).get("name")
        for e in events
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    if names.get(0) != "puzzle-router":
        fail(f"fleet: pid 0 must be named puzzle-router, got {names.get(0)!r}")
    replicas = sorted(p for p in names if p != 0)
    if not replicas:
        fail("fleet: no replica processes (pid >= 1) are named")
    for p in replicas:
        want = f"puzzle-replica-{p - 1}"
        if names[p] != want:
            fail(f"fleet: pid {p} must be named {want!r}, got {names[p]!r}")

    # 2. Routing instants live on the router's tid-0 track.
    routed = [e for e in events if e["name"] == "routed"]
    for e in routed:
        if e["pid"] != 0 or e["tid"] != 0:
            fail(f"fleet: routed instant on pid {e['pid']} tid {e['tid']}, expected pid 0 tid 0")
    if not routed:
        fail("fleet: no routed instants on the router timeline")

    # 3. Cross-pid stitching: every stitched pid-0 request resolves to a
    # replica-side request track carrying the same global id, and the
    # id's high bits encode that replica.
    replica_reqs = {
        (e["pid"], e["tid"])
        for e in events
        if e["ph"] == "X" and e["name"] == "request" and e["pid"] != 0 and e["tid"] >= TID_REQ_BASE
    }
    stitched = 0
    for e in events:
        if e["ph"] != "X" or e["name"] != "request" or e["pid"] != 0 or e["tid"] < TID_REQ_BASE:
            continue
        args = e.get("args", {})
        rid, rep = args.get("id"), args.get("replica")
        if rid is None or rep is None:
            fail(f"fleet: pid-0 request track tid {e['tid']} lacks id/replica args")
        if int(rid) >> REPLICA_SHIFT != int(rep):
            fail(f"fleet: request id {rid} does not encode replica {rep} in its high bits")
        if (int(rep) + 1, TID_REQ_BASE + int(rid)) not in replica_reqs:
            fail(f"fleet: request {rid} routed to replica {rep} has no track on pid {int(rep) + 1}")
        stitched += 1
    if stitched == 0:
        fail("fleet: no stitched per-request tracks on the router pid")

    # 4. Migration spans must be paired (the exporter demotes a begin
    # without its end to a migration_unpaired marker).
    unpaired = [e for e in events if e["name"] == "migration_unpaired"]
    if unpaired:
        fail(f"fleet: {len(unpaired)} unpaired migration begin(s) in the trace")
    migrations = [e for e in events if e["ph"] == "X" and e["name"] == "migration"]
    for e in migrations:
        if e["pid"] != 0:
            fail(f"fleet: migration span on pid {e['pid']}, expected the router pid 0")
        for k in ("mig", "src", "dst", "seg", "tokens", "adopted"):
            if k not in e.get("args", {}):
                fail(f"fleet: migration span missing arg {k!r}")
    adopted = sum(1 for e in migrations if e["args"].get("adopted") is True)
    return len(replicas), len(routed), adopted


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file (--trace-out output)")
    ap.add_argument(
        "--fleet",
        action="store_true",
        help="expect a merged fleet trace (router pid 0 + replica pids), "
        "checking pid naming, cross-pid stitching, and migration pairing",
    )
    ap.add_argument(
        "--expect-spec",
        action="store_true",
        help="require at least one spec_round event (speculative serving)",
    )
    ap.add_argument(
        "--expect-prefix-hit",
        action="store_true",
        help="require at least one request admitted with a prefix-cache hit",
    )
    ap.add_argument(
        "--expect-migration",
        action="store_true",
        help="require at least one adopted migration span (--fleet only)",
    )
    opts = ap.parse_args()

    doc = load(opts.trace)
    events = doc["traceEvents"]
    if not events:
        fail("traceEvents is empty")
    check_shape(events)
    check_monotonic(events)
    if opts.fleet:
        lifecycle_for_pid = lambda pid: FLEET_LIFECYCLE if pid == 0 else LIFECYCLE
    else:
        lifecycle_for_pid = lambda pid: LIFECYCLE
    n_req, n_complete, n_hits = check_requests(events, lifecycle_for_pid)
    if n_req == 0:
        fail("no request tracks (tid >= 1000) in the trace")

    n_steps = sum(1 for e in events if e["name"] == "step")
    n_spec = sum(1 for e in events if e["name"] == "spec_round")
    if n_steps == 0 and n_spec == 0:
        fail("neither engine steps nor speculative rounds were recorded")
    if opts.expect_spec and n_spec == 0:
        fail("--expect-spec: no spec_round events in the trace")
    if opts.expect_prefix_hit and n_hits == 0:
        fail("--expect-prefix-hit: no request was admitted with a prefix-cache hit")

    fleet_note = ""
    if opts.fleet:
        n_replicas, n_routed, n_migrations = check_fleet(events)
        if opts.expect_migration and n_migrations == 0:
            fail("--expect-migration: no adopted migration spans in the trace")
        fleet_note = f", {n_replicas} replicas, {n_routed} routed, {n_migrations} migrations"
    elif opts.expect_migration:
        fail("--expect-migration only makes sense with --fleet")

    print(
        f"verify_trace: ok: {len(events)} events, {n_req} requests "
        f"({n_complete} complete lifecycles, {n_hits} prefix hits), "
        f"{n_steps} steps, {n_spec} spec rounds{fleet_note}"
    )


if __name__ == "__main__":
    main()
