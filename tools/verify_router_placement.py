#!/usr/bin/env python3
"""Toolchain-free cross-check of the PR 9 data-parallel router logic.

Transcribes the pure decision/accounting pieces of
rust/src/server/placement.rs, rust/src/server/router.rs, and the
migration geometry of rust/src/serving/{prefixcache,kvcache}.rs to
Python (no cargo in the growth container) and checks:

1. placement.rs unit-test expectations replayed against the transcribed
   `choose` (all four tests, every assert).
2. The placement.rs property fuzz replayed EXACTLY: a transcription of
   util::Rng (PCG32) drives the same 5 seeds x 300 ops through the same
   naive model, asserting choose == naive argmax on every submit and
   shed iff all full — pre-verifying the Rust test stream-for-stream.
3. An independent property fuzz (Python random, finite overloads, full
   flags): shed-iff-all-full, the hoist rule (best non-overloaded
   candidate wins, equal misery falls back to affinity), the fallback
   chain is the rank order with the target hoisted, and migrate_from
   points at the longest-match replica iff it beats the target.
4. The warm/pin/spill migration sequence of
   tests/router_integration.rs: probes transcribed step by step must
   route warm->0, pin->0, spill->1 with exactly one migration of 8
   tokens (11-token shared prefix aligned down to page 4), and the
   routed-per-replica count must come out [2, 1, 0, 0].
5. Request-id partitioning: `set_request_id_base` (next_id =
   max(next_id, max(base, 1))) over REPLICA_SHIFT=48 keeps replica 0's
   ids starting at 1, makes all ids globally unique, and `id >> 48`
   recovers the owning replica for every issued id.
6. Migration geometry: KvSegment::truncated / host_bytes transcribed
   (incl. the 24-float unit anchor) and checked against
   PagedKvManager::shared_bytes for page-aligned lengths over variable
   kv-head layouts — the adopt_prefix equality gate — plus rejection of
   a mismatched geometry.
"""

import math
import random
import sys

# ---------------------------------------------------------------- PCG32

M64 = (1 << 64) - 1


class Rng:
    """util/rng.rs PCG32, bit-exact."""

    def __init__(self, seed):
        self.state = 0
        self.inc = ((seed << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + (0x853C49E6748FEA9B ^ seed)) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_u64(self):
        return (self.next_u32() << 32) | self.next_u32()

    def below(self, n):
        assert n > 0
        return self.next_u64() % n


# ------------------------------------------------- placement.rs choose


class Probe:
    def __init__(self, match_len, active, queued, full):
        self.match_len = match_len
        self.active = active
        self.queued = queued
        self.full = full

    def depth(self):
        return self.active + self.queued

    def __repr__(self):
        return f"P(m={self.match_len},d={self.depth()},f={self.full})"


def choose(probes, overload):
    """Transcription of placement::choose (overload may be math.inf)."""
    order = [i for i, p in enumerate(probes) if not p.full]
    if not order:
        return None
    # descending by (match_len, Reverse(depth), Reverse(index))
    order.sort(key=lambda i: (probes[i].match_len, -probes[i].depth(), -i), reverse=True)
    pos = next((k for k, i in enumerate(order) if probes[i].depth() < overload), None)
    if pos is not None:
        order.insert(0, order.pop(pos))
    target = order[0]
    best = max(range(len(probes)), key=lambda i: (probes[i].match_len, -i))
    migrate_from = best if probes[best].match_len > probes[target].match_len else None
    return order, migrate_from


def probe(match_len, depth, full):
    return Probe(match_len, depth, 0, full)


INF = math.inf


def check_unit_tests():
    # longest_match_wins_then_depth_then_index
    order, mig = choose([probe(0, 0, False), probe(8, 2, False), probe(8, 1, False)], INF)
    assert order[0] == 2 and mig is None, (order, mig)
    order, _ = choose([probe(4, 3, False), probe(0, 0, False)], INF)
    assert order[0] == 0, "match beats depth"
    order, _ = choose([probe(0, 1, False), probe(0, 1, False)], INF)
    assert order[0] == 0, "ties break low-index"
    # sheds_iff_all_full
    assert choose([probe(9, 0, True), probe(0, 0, True)], INF) is None
    order, mig = choose([probe(9, 0, True), probe(0, 5, False)], INF)
    assert order == [1] and mig == 0, (order, mig)
    assert choose([], INF) is None
    # overloaded_best_match_loses_pick_and_becomes_migration_source
    probes = [probe(8, 2, False), probe(0, 0, False)]
    order, mig = choose(probes, 2)
    assert order == [1, 0] and mig == 0, (order, mig)
    order, mig = choose(probes, 3)
    assert (order[0], mig) == (0, None)
    order, mig = choose([probe(8, 4, False), probe(0, 4, False)], 2)
    assert (order[0], mig) == (0, None), "equal misery: affinity wins"
    # order_is_a_permutation_of_the_non_full_replicas (expectation fixed
    # by this verifier: at overload 2, replica 0's depth-1 queue is below
    # the threshold and its match outranks idle replica 3)
    probes = [probe(2, 1, False), probe(0, 0, True), probe(6, 3, False), probe(0, 0, False)]
    order, _ = choose(probes, 1)
    assert sorted(order) == [0, 2, 3] and order == [3, 2, 0], order
    order, _ = choose(probes, 2)
    assert order[0] == 0, order
    print("1. placement.rs unit-test expectations replayed (4 tests, every assert) ✓")


def check_rust_fuzz_exact():
    """Replay placement_matches_naive_model_under_fuzz stream-for-stream."""
    REPLICAS, CAP, PAGE = 4, 3, 2
    total_placed = 0
    for fuzz_seed in range(5):
        rng = Rng(0x907E12 ^ fuzz_seed)
        retained = [[] for _ in range(REPLICAS)]
        depth = [0] * REPLICAS
        inflight = []
        placed = 0
        for _ in range(300):
            op = rng.below(10)
            if op < 5:
                prompt = []
                if rng.below(2) == 0:
                    r = rng.below(REPLICAS)
                    if retained[r]:
                        prompt = list(retained[r][rng.below(len(retained[r]))])
                while len(prompt) < 2 or rng.below(3) > 0:
                    prompt.append(rng.below(3))
                    if len(prompt) >= 8:
                        break
                probes = []
                for r in range(REPLICAS):
                    match_len = max(
                        (len(q) for q in retained[r]
                         if len(q) < len(prompt) and prompt[: len(q)] == q),
                        default=0,
                    )
                    probes.append(
                        Probe(match_len, min(depth[r], 2), max(depth[r] - 2, 0),
                              depth[r] >= CAP))
                decision = choose(probes, INF)
                live = [r for r in range(REPLICAS) if depth[r] < CAP]
                naive = max(
                    live, key=lambda r: (probes[r].match_len, -depth[r], -r), default=None)
                if decision is None:
                    assert naive is None, f"seed {fuzz_seed}: shed disagreement"
                else:
                    assert naive is not None, f"seed {fuzz_seed}: shed disagreement"
                    order, _ = decision
                    assert order[0] == naive, \
                        f"seed {fuzz_seed}: choose {order[0]} != naive {naive} for {probes}"
                    depth[naive] += 1
                    inflight.append((naive, prompt))
                    placed += 1
            elif inflight:
                i = rng.below(len(inflight))
                # Vec::swap_remove
                inflight[i], inflight[-1] = inflight[-1], inflight[i]
                r, prompt = inflight.pop()
                depth[r] -= 1
                aligned = (len(prompt) // PAGE) * PAGE
                if op < 8 and aligned > 0 and not any(
                        len(q) == aligned and prompt[: len(q)] == q for q in retained[r]):
                    retained[r].append(prompt[:aligned])
        assert placed > 50, f"seed {fuzz_seed}: only {placed} placed"
        total_placed += placed
    print(f"2. Rust placement fuzz replayed exactly (PCG32, 5 seeds x 300 ops, "
          f"{total_placed} placements, choose == naive argmax throughout) ✓")


def check_independent_fuzz():
    pyrng = random.Random(0x9077)
    trials = shed = migs = hoists = 0
    for _ in range(4000):
        n = pyrng.randrange(1, 7)
        overload = pyrng.choice([1, 2, 3, INF])
        probes = [
            Probe(pyrng.choice([0, 0, 2, 4, 8, 8, 16]), pyrng.randrange(0, 4),
                  pyrng.randrange(0, 3), pyrng.random() < 0.25)
            for _ in range(n)
        ]
        got = choose(probes, overload)
        alive = [i for i in range(n) if not probes[i].full]
        if not alive:
            assert got is None, probes
            shed += 1
            continue
        assert got is not None, probes
        order, mig = got
        # order: permutation of the non-full replicas
        assert sorted(order) == sorted(alive), (order, alive)
        # rank order from the spec
        rank = sorted(alive, key=lambda i: (probes[i].match_len, -probes[i].depth(), -i),
                      reverse=True)
        calm = [i for i in rank if probes[i].depth() < overload]
        want_target = calm[0] if calm else rank[0]
        assert order[0] == want_target, (order, rank, calm, overload, probes)
        if calm and calm[0] != rank[0]:
            hoists += 1
        # fallback chain: rank order with the target hoisted out
        want_order = [want_target] + [i for i in rank if i != want_target]
        assert order == want_order, (order, want_order)
        # migration source: longest match overall (low index ties) iff it
        # beats the target's own match — full replicas included
        best = max(range(n), key=lambda i: (probes[i].match_len, -i))
        want_mig = best if probes[best].match_len > probes[want_target].match_len else None
        assert mig == want_mig, (mig, want_mig, probes)
        if mig is not None:
            migs += 1
        trials += 1
    assert trials > 2000 and shed > 50 and migs > 100 and hoists > 50, \
        (trials, shed, migs, hoists)
    print(f"3. independent property fuzz ok ({trials} placements, {shed} sheds, "
          f"{migs} migrations, {hoists} overload hoists — all rules exact) ✓")


def check_warm_pin_spill():
    """The deterministic migration sequence of tests/router_integration.rs."""
    PAGE, SHARED_LEN, REPLICAS, OVERLOAD = 4, 11, 4, 1
    aligned = (SHARED_LEN // PAGE) * PAGE
    assert aligned == 8, aligned
    routed = [0] * REPLICAS
    migrations = migrated_tokens = 0

    def submit(probes):
        nonlocal migrations, migrated_tokens
        order, mig = choose(probes, OVERLOAD)
        if mig is not None and probes[mig].match_len >= 1:  # min_migrate: 1
            migrations += 1
            migrated_tokens += probes[mig].match_len
        routed[order[0]] += 1
        return order[0], mig

    # warm: cold fleet, all probes (0, depth 0) -> replica 0 (low index),
    # runs to completion (depth back to 0), retains the 8-token prefix
    t, mig = submit([probe(0, 0, False)] * REPLICAS)
    assert (t, mig) == (0, None), (t, mig)
    # pin: replica 0 matches 8 at depth 0 (below overload) -> stays home,
    # held open so replica 0's depth becomes 1 == overload
    probes = [probe(aligned, 0, False)] + [probe(0, 0, False)] * 3
    t, mig = submit(probes)
    assert (t, mig) == (0, None), (t, mig)
    # spill: replica 0 still holds the match but sits at the overload
    # threshold -> hoist picks replica 1, dragging the segment along
    probes = [probe(aligned, 1, False)] + [probe(0, 0, False)] * 3
    t, mig = submit(probes)
    assert (t, mig) == (1, 0), (t, mig)
    assert routed == [2, 1, 0, 0], routed
    assert (migrations, migrated_tokens) == (1, 8), (migrations, migrated_tokens)
    print("4. warm/pin/spill sequence exact: routed [2,1,0,0], 1 migration of 8 tokens "
          "(11-token shared prefix aligned down to page 4) ✓")


def check_id_partitioning():
    SHIFT = 48
    issued = set()
    for n in (1, 2, 4):
        per = []
        for i in range(n):
            next_id = 1  # Engine::new starts ids at 1
            base = i << SHIFT
            next_id = max(next_id, max(base, 1))  # set_request_id_base
            ids = []
            for _ in range(5):
                ids.append(next_id)
                next_id += 1
            per.append(ids)
        flat = [x for ids in per for x in ids]
        assert len(set(flat)) == len(flat), "ids must be globally unique"
        issued |= set(flat)
        assert per[0][0] == 1, "replica 0 keeps the bare-engine id space"
        for i, ids in enumerate(per):
            assert all(x >> SHIFT == i for x in ids), (i, ids)
    assert max(issued) < (4 << SHIFT) + 5 and (1 << SHIFT) in issued
    print("5. request-id partitioning ok: replica 0 starts at 1, ids unique, "
          "id >> 48 recovers the replica for every issued id ✓")


def check_migration_geometry():
    F32 = 4

    def host_bytes(layers):
        return sum((len(k) + len(v)) * F32 for l in layers if l for (k, v) in [l])

    def truncated(seg_len, layers, new_len):
        out = []
        for l in layers:
            if l is None:
                out.append(None)
            else:
                k, v = l
                row = len(k) // seg_len
                out.append((k[: new_len * row], v[: new_len * row]))
        return out

    # the prefixcache.rs unit anchor: rows 16/None/8 floats, truncate 4 -> 2
    layers = [
        (list(range(16)), [-x for x in range(16)]),
        None,
        (list(range(8)), [1.0] * 8),
    ]
    t = truncated(4, layers, 2)
    assert t[0][0] == list(range(8)) and t[0][1] == [-x for x in range(8)]
    assert t[1] is None
    assert len(t[2][0]) == 4 and t[2][1] == [1.0] * 4
    assert host_bytes(t) == 24 * F32
    assert host_bytes(truncated(4, layers, 4)) == host_bytes(layers)

    # adopt_prefix's gate: for page-aligned len, cloned host bytes must
    # equal the destination pool charge (shared_bytes) — and a different
    # kv-head layout must be caught by that same equality
    def shared_bytes(kv_heads, head_dim, page_len, positions):
        pages = -(-positions // page_len)  # div_ceil
        return sum(
            0 if h == 0 else pages * (2 * h * head_dim * page_len * F32)
            for h in kv_heads)

    pyrng = random.Random(7)
    for _ in range(500):
        page_len = pyrng.choice([2, 4])
        head_dim = pyrng.choice([2, 4])
        kv_heads = [pyrng.choice([0, 1, 2, 4]) for _ in range(pyrng.randrange(1, 5))]
        pages = pyrng.randrange(1, 5)
        length = pages * page_len  # export aligns down, so len is aligned
        layers = [
            None if h == 0 else
            ([0.0] * (length * h * head_dim), [0.0] * (length * h * head_dim))
            for h in kv_heads
        ]
        assert host_bytes(layers) == shared_bytes(kv_heads, head_dim, page_len, length), \
            (kv_heads, head_dim, page_len, length)
        # a destination with a different layout rejects by byte mismatch
        other = [h + 1 for h in kv_heads]
        assert host_bytes(layers) != shared_bytes(other, head_dim, page_len, length)
        # truncating to fewer aligned rows keeps the equality
        if pages > 1:
            short = (pages - 1) * page_len
            assert host_bytes(truncated(length, layers, short)) == \
                shared_bytes(kv_heads, head_dim, page_len, short)
    print("6. migration geometry ok: truncated/host_bytes anchor replayed, "
          "host_bytes == shared_bytes for aligned lengths over 500 random "
          "variable-kv-head layouts, mismatched layouts rejected ✓")


def main():
    check_unit_tests()
    check_rust_fuzz_exact()
    check_independent_fuzz()
    check_warm_pin_spill()
    check_id_partitioning()
    check_migration_geometry()
    print("all router placement/migration cross-checks passed")


if __name__ == "__main__":
    sys.exit(main())
