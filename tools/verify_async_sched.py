#!/usr/bin/env python3
"""Toolchain-free cross-check of PR 7's serving-side logic.

The growth container has no cargo/rustc, so this script transcribes the
new budgeted chunked-prefill bookkeeping (rust/src/serving/engine.rs:
`prefill`'s budget branch, `prefill_chunks`, `decode_step`'s
teacher-forcing tail) and the aged scheduler keys
(rust/src/serving/scheduler.rs) to plain Python and checks them against
naive oracles:

  1. byte-identity fuzz: a multi-lane engine simulation running the
     budgeted ingestion schedule must produce generated streams
     identical to an isolated, unbatched, unchunked oracle for every
     request, across random prompt/max_new mixes, budgets, and lane
     counts. The fake model's output depends on the FULL committed KV
     row history and a per-request seeded rng stream, so any divergence
     in what rows get written, in what order, or when sampling starts
     breaks equality.
  2. invariants along the way: committed rows per lane always equal
     prompt[:len] ++ generated-so-far, a chunk pass never feeds more
     than `budget` prompt tokens, the head only samples after full
     ingestion, and every pending queue drains exactly once.
  3. scheduler transcription: every hardcoded expectation in
     scheduler.rs's unit tests is replayed against the transcribed
     keys, plus a starvation simulation — under a sustained stream of
     short (or cache-hot) arrivals, the aged SPF/PrefixAffinity keys
     admit a long (or cache-cold) prompt within its documented bound,
     while the same keys WITHOUT the `waited` term starve it forever.
  4. head-of-line bound: the serving_integration.rs regression-test
     arithmetic (per-step chunk-metric delta <= budget, live lane emits
     exactly one token per step, monster TTFT <= ceil(need/(budget+1))
     + 2) is replayed exactly with the test's own numbers.

Run: python3 tools/verify_async_sched.py
"""

import math
import random
import sys

VOCAB = 128
EOS = None  # the fake model never emits EOS; max_new terminates


# ---------------------------------------------------------------------------
# fake model + per-request rng: deterministic functions of the committed
# row history, so two schedules agree iff they commit identical rows in
# identical order and draw the rng at identical points.
# ---------------------------------------------------------------------------

class ReqRng:
    """Stand-in for the per-request seeded PCG32 stream (sampling.rs):
    what matters for the cross-check is that both schedules draw the
    same number of times from the same seed."""

    def __init__(self, seed):
        self.state = (seed * 6364136223846793005 + 1442695040888963407) % (1 << 64)

    def next(self):
        self.state = (self.state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        return self.state >> 33


def fake_sample(rows, rng):
    """Next token = f(entire committed row history, rng draw). Mirrors
    the property that logits at the frontier depend on every cached
    row; the rng draw mirrors stochastic sampling's stream position."""
    acc = 0
    for i, t in enumerate(rows):
        acc = (acc * 1000003 + (i + 1) * (t + 7)) % (1 << 61)
    return (acc + rng.next()) % VOCAB


# ---------------------------------------------------------------------------
# oracle: one request at a time, whole prompt ingested at once (the
# unchunked prefill path), then plain decode. No batching, no budget.
# ---------------------------------------------------------------------------

def oracle_generate(prompt, max_new, seed):
    rows = list(prompt)  # prefill writes every prompt row
    rng = ReqRng(seed)
    out = []
    for _ in range(max_new):
        nxt = fake_sample(rows, rng)
        out.append(nxt)
        rows.append(nxt)
    return out


# ---------------------------------------------------------------------------
# budgeted engine simulation: transcribed from engine.rs. Slots hold
# (len, last_token, pending, rows, generated, rng, max_new). Admission
# books a lane and queues the whole prompt (budget branch of
# `prefill`); `prefill_chunks` spends <= budget tokens per step in lane
# order; `decode_step` writes one row per active lane and either
# teacher-forces the next pending token or samples.
# ---------------------------------------------------------------------------

class Slot:
    def __init__(self, req_id, prompt, max_new, seed):
        assert len(prompt) >= 1
        self.id = req_id
        self.prompt = list(prompt)
        self.max_new = max_new
        self.rng = ReqRng(seed)
        self.len = 0
        self.last_token = prompt[0]
        self.pending = list(prompt[1:])
        self.rows = []          # committed KV rows, by position
        self.generated = []


class BudgetedEngine:
    def __init__(self, lanes, budget):
        self.lanes = [None] * lanes
        self.budget = budget  # None = chunking off (oracle-style prefill)
        self.queue = []       # FIFO of (id, prompt, max_new, seed)
        self.finished = {}
        self.chunk_tokens = 0
        self.chunk_passes = 0
        self.step_chunk_fed = 0   # this step's chunk-pass feed (metric delta)

    def submit(self, req_id, prompt, max_new, seed):
        self.queue.append((req_id, prompt, max_new, seed))

    def _admit(self):
        for i in range(len(self.lanes)):
            if self.lanes[i] is None and self.queue:
                req_id, prompt, max_new, seed = self.queue.pop(0)
                if self.budget is None:
                    # unchunked window prefill: every prompt row written
                    # at admission, sampling starts next decode step
                    s = Slot(req_id, prompt, max_new, seed)
                    s.rows = list(prompt)
                    s.len = len(prompt)
                    s.pending = []
                    s.last_token = None  # head ran at prefill: sample now
                    nxt = fake_sample(s.rows, s.rng)
                    s.generated.append(nxt)
                    s.last_token = nxt
                    self.lanes[i] = s
                    self._maybe_finish(i)
                else:
                    self.lanes[i] = Slot(req_id, prompt, max_new, seed)

    def _prefill_chunks(self):
        self.step_chunk_fed = 0
        if self.budget is None:
            return
        left = self.budget
        plan = []
        for lane, s in enumerate(self.lanes):
            if left == 0:
                break
            if s is None or not s.pending:
                continue
            c = min(left, len(s.pending))
            chunk = [s.last_token] + s.pending[: c - 1]
            left -= c
            plan.append((lane, s.len, chunk))
        if not plan:
            return
        fed = 0
        for lane, start, chunk in plan:
            s = self.lanes[lane]
            c = len(chunk)
            assert start == len(s.rows), "chunk must start at the frontier"
            s.rows.extend(chunk)           # feeds_forward writes rows start..start+c
            s.len += c
            del s.pending[: c - 1]
            s.last_token = s.pending.pop(0)
            fed += c
        self.chunk_passes += 1
        self.chunk_tokens += fed
        self.step_chunk_fed = fed
        assert fed <= self.budget, f"chunk pass fed {fed} > budget {self.budget}"

    def _maybe_finish(self, lane):
        s = self.lanes[lane]
        if len(s.generated) >= s.max_new:
            self.finished[s.id] = s.generated
            self.lanes[lane] = None

    def _decode_step(self):
        to_finish = []
        for i, s in enumerate(self.lanes):
            if s is None:
                continue
            # decode writes row `len` with token `last_token`
            assert s.len == len(s.rows)
            s.rows.append(s.last_token)
            s.len += 1
            if s.pending:
                s.last_token = s.pending.pop(0)
                continue
            # invariant: sampling only ever happens with the full prompt
            # (and any earlier generations) committed
            expect = s.prompt + s.generated
            assert s.rows == expect, (
                f"lane {i} sampled over rows != prompt+generated: "
                f"{s.rows} vs {expect}"
            )
            nxt = fake_sample(s.rows, s.rng)
            s.generated.append(nxt)
            s.last_token = nxt
            to_finish.append(i)
        for i in to_finish:
            self._maybe_finish(i)

    def step(self):
        self._admit()
        self._prefill_chunks()
        if any(s is not None for s in self.lanes):
            self._decode_step()

    def idle(self):
        return not self.queue and all(s is None for s in self.lanes)

    def run(self, max_steps=100_000):
        steps = 0
        while not self.idle():
            self.step()
            steps += 1
            assert steps < max_steps, "engine failed to drain"
        return steps


def check_budget_byte_identity():
    rnd = random.Random(0xB0D6E7)
    cases = 0
    for trial in range(60):
        lanes = rnd.choice([1, 2, 3, 4])
        budget = rnd.choice([1, 2, 3, 5, 8, 16])
        nreq = rnd.randrange(1, 9)
        reqs = []
        for r in range(nreq):
            plen = rnd.randrange(1, 41)
            prompt = [rnd.randrange(VOCAB) for _ in range(plen)]
            max_new = rnd.randrange(1, 9)
            seed = rnd.randrange(1 << 31)
            reqs.append((r, prompt, max_new, seed))
        eng = BudgetedEngine(lanes, budget)
        for req in reqs:
            eng.submit(*req)
        eng.run()
        assert eng.chunk_tokens > 0 and eng.chunk_passes > 0
        for r, prompt, max_new, seed in reqs:
            want = oracle_generate(prompt, max_new, seed)
            got = eng.finished[r]
            assert got == want, (
                f"trial {trial} req {r}: budgeted stream {got} != oracle {want} "
                f"(lanes={lanes} budget={budget} plen={len(prompt)})"
            )
            cases += 1
        # the same trial through the UNCHUNKED simulation must also match
        # (sanity that the oracle and the window path agree)
        plain = BudgetedEngine(lanes, None)
        for req in reqs:
            plain.submit(*req)
        plain.run()
        assert plain.chunk_tokens == 0
        for r, prompt, max_new, seed in reqs:
            assert plain.finished[r] == eng.finished[r]
    print(f"[1] budgeted byte-identity fuzz ok: {cases} request streams "
          f"identical to the unbatched oracle (and to unchunked batching)")


# ---------------------------------------------------------------------------
# scheduler keys, transcribed. Rust max_by_key keeps the LAST max and
# min_by_key keeps the FIRST min; the tie-breakers in scheduler.rs fold
# the index into the key so the iteration-order subtlety never decides —
# we transcribe key-only and resolve ties exactly like the Rust tuples.
# ---------------------------------------------------------------------------

def pick_fifo(queue):
    return 0 if queue else None


def pick_priority(queue):
    if not queue:
        return None
    # max_by_key (priority, Reverse(i)) == max over (priority, -i)
    return max(range(len(queue)), key=lambda i: (queue[i]["priority"], -i))


def pick_spf(queue, aged=True):
    if not queue:
        return None
    def key(i):
        q = queue[i]
        eff = max(0, q["prompt_len"] - q["waited"]) if aged else q["prompt_len"]
        return (eff, i)
    return min(range(len(queue)), key=key)


def pick_prefix(queue, aged=True):
    if not queue:
        return None
    def key(i):
        q = queue[i]
        eff = q["cached_prefix"] + (q["waited"] if aged else 0)
        return (eff, -i)
    return max(range(len(queue)), key=key)


def qv(priority=0, prompt_len=4, cached=0, waited=0):
    return {"priority": priority, "prompt_len": prompt_len,
            "cached_prefix": cached, "waited": waited}


def check_scheduler_unit_expectations():
    # literal replay of scheduler.rs's #[cfg(test)] assertions
    assert pick_fifo([]) is None
    assert pick_fifo([qv(), qv(priority=9)]) == 0
    assert pick_priority([qv(0), qv(5), qv(5), qv(1)]) == 1
    assert pick_priority([qv(2), qv(2)]) == 0
    assert pick_priority([]) is None
    assert pick_spf([qv(prompt_len=9), qv(prompt_len=3), qv(prompt_len=3)]) == 1
    assert pick_spf([]) is None
    assert pick_prefix([qv(cached=0), qv(cached=16), qv(cached=8), qv(cached=16)]) == 1
    assert pick_prefix([qv(cached=0), qv(cached=0)]) == 0
    assert pick_prefix([]) is None
    # spf_aging_lifts_a_starved_long_prompt
    assert pick_spf([qv(prompt_len=12, waited=4), qv(prompt_len=3, waited=0)]) == 1
    assert pick_spf([qv(prompt_len=12, waited=10), qv(prompt_len=3, waited=0)]) == 0
    assert pick_spf([qv(prompt_len=12, waited=50), qv(prompt_len=3, waited=50)]) == 0
    # prefix_affinity_aging_lifts_a_cache_cold_prompt
    assert pick_prefix([qv(cached=0, waited=4), qv(cached=16, waited=0)]) == 1
    assert pick_prefix([qv(cached=0, waited=17), qv(cached=16, waited=0)]) == 0
    assert pick_prefix([qv(cached=0, waited=16), qv(cached=16, waited=0)]) == 0
    print("[2] scheduler key transcription ok: all scheduler.rs unit-test "
          "expectations replayed")


def check_starvation_freedom():
    def simulate(pick, make_victim, make_fresh, aged, steps=300):
        """One admission per step; a fresh rival arrives every step; all
        waiters age by one per step (QueueView.waited = steps queued).
        Returns the step the victim was admitted, or None."""
        queue = [make_victim()]
        for step in range(steps):
            queue.append(make_fresh())
            i = pick(queue, aged=aged)
            if queue[i] is queue[0] and queue[0]["victim"]:
                return step
            del queue[i]
            for q in queue:
                q["waited"] += 1
        return None

    def victim_long():
        q = qv(prompt_len=12)
        q["victim"] = True
        return q

    def fresh_short():
        q = qv(prompt_len=3)
        q["victim"] = False
        return q

    t = simulate(pick_spf, victim_long, fresh_short, aged=True)
    assert t is not None and t <= 12, f"aged SPF must admit within prompt_len steps, got {t}"
    t0 = simulate(pick_spf, victim_long, fresh_short, aged=False)
    assert t0 is None, "un-aged SPF must starve the long prompt (it was the bug)"

    def victim_cold():
        q = qv(prompt_len=20, cached=0)
        q["victim"] = True
        return q

    def fresh_hot():
        q = qv(prompt_len=20, cached=16)
        q["victim"] = False
        return q

    t = simulate(pick_prefix, victim_cold, fresh_hot, aged=True)
    assert t is not None and t <= 17, f"aged PrefixAffinity must admit within s_max steps, got {t}"
    t0 = simulate(pick_prefix, victim_cold, fresh_hot, aged=False)
    assert t0 is None, "un-aged PrefixAffinity must starve the cache-cold prompt"
    print(f"[3] starvation-freedom ok: aged keys admit the victim "
          f"(SPF and PrefixAffinity); the un-aged keys starve it for 300 steps")


# ---------------------------------------------------------------------------
# head-of-line bound, with the regression tests' exact numbers: a live
# self-loop lane emitting one token per step, then a monster prompt is
# admitted. Per step the monster may ingest at most budget (chunk pass)
# + 1 (teacher-forcing decode tail) tokens, the live lane's cadence is
# untouched, and the monster's first token lands within
# ceil(need/(budget+1)) + 2 steps.
# ---------------------------------------------------------------------------

def check_head_of_line(budget, monster_len, label):
    y = 5
    eng = BudgetedEngine(lanes=2, budget=budget)
    eng.submit(0, [1, y], max_new=10_000, seed=1)  # live lane, effectively unbounded
    eng.step()  # admits + ingests the 2-token prompt + first decode
    # a couple of plain decode steps first (mirrors the test's warmup)
    for _ in range(2):
        eng.step()
    live = eng.lanes[0]
    assert live is not None and len(live.generated) == 3
    monster = [1] + [y] * (monster_len - 1)
    eng.submit(1, monster, max_new=2, seed=2)
    need = monster_len - 1  # tokens beyond the admission-time first token
    steps = 0
    before_chunk = eng.chunk_tokens
    while True:
        live_before = len(live.generated)
        m_before = None
        for s in eng.lanes:
            if s is not None and s.id == 1:
                m_before = len(s.generated)
        eng.step()
        steps += 1
        # (a) chunk-pass metric delta bounded by the budget, every step
        assert eng.step_chunk_fed <= budget
        # (b) the live lane's cadence is completely unaffected
        assert len(live.generated) == live_before + 1, (
            f"{label}: live lane stalled at step {steps}"
        )
        m_now = 0
        for s in eng.lanes:
            if s is not None and s.id == 1:
                m_now = len(s.generated)
        if m_before is not None and m_now > 0:
            break
        assert steps < 10_000
    bound = math.ceil(need / (budget + 1)) + 2
    assert steps <= bound, (
        f"{label}: monster first token took {steps} steps, bound {bound}"
    )
    total_ingested = eng.chunk_tokens - before_chunk
    assert total_ingested <= need, f"{label}: chunk metric over-counted"
    print(f"[4] head-of-line ok ({label}): first token after {steps} steps "
          f"(bound {bound}), chunk deltas <= {budget} throughout, "
          f"live lane never skipped a beat")


def check_cancel_window():
    # the cancellation tests cancel right after submitting a 44-token
    # monster under budgets 2 and 3; verify ingestion genuinely spans
    # multiple steps (>= 5), so a cancel one control-message later is
    # guaranteed to land mid-ingest, and that after 3 steps at budget 3
    # the chunk metric is still < need (the serving_integration assert).
    for budget in (2, 3):
        need = 43
        per_step = budget + 1  # chunk pass + decode teacher-forcing tail
        steps_to_ingest = math.ceil(need / per_step)
        assert steps_to_ingest >= 5, (budget, steps_to_ingest)
    assert 3 * 3 < 43  # three steps x budget-3 chunk feeds, strictly mid-ingest
    print("[5] cancel-mid-ingest window ok: 44-token monster needs >= 5 steps "
          "at budgets 2 and 3; the tests' cancel always lands mid-flight")


def main():
    check_budget_byte_identity()
    check_scheduler_unit_expectations()
    check_starvation_freedom()
    check_head_of_line(budget=4, monster_len=44, label="serving_integration, budget 4")
    check_head_of_line(budget=2, monster_len=44, label="server_integration, budget 2")
    check_cancel_window()
    print("all PR 7 cross-checks passed")


if __name__ == "__main__":
    sys.exit(main())
