#!/usr/bin/env python3
"""Toolchain-free cross-checks of the prefix-cache subsystem (PR 5).

The growth container has no cargo, so this verifies the load-bearing
claims of rust/src/serving/prefixcache.rs + engine.rs in python:

  1. hit == miss, exactly: a full-model cold prefill of a prompt versus
     importing the retained K/V prefix and teacher-forcing only the
     suffix through decode steps — transcribed loop-for-loop from
     refbackend.rs (per-row rmsnorm/rope/matmul, causal attention with
     grouped KV heads, variable kv-heads per layer, tied head) — must
     produce bitwise-identical suffix hidden rows, cache rows, and
     final logits. This is the inductive argument DESIGN.md §7 leans on.
  2. the prefill transcription is anchored against the independent JAX
     oracles (compile/model.py attn_gqa_fwd / ffn_fwd / head_fwd).
  3. radix tree fuzz: a python port of PrefixCache::{insert, best_match,
     covered, remove} checked against a brute-force oracle (the best hit
     is the max over retained paths of align_down(min(common, P-1))) on
     thousands of random small-alphabet workloads.
  4. shared-page accounting fuzz: a python port of PagedKvManager's
     retain/admit_shared/grow/truncate/release/evict checked after every
     op against from-scratch recomputation of the pool accounting
     (segment bytes charged once + per-sequence owned bytes) and the
     refcount/eviction rules.

Run: PYTHONPATH=python python3 tools/verify_prefixcache_numpy.py
"""
import numpy as np

rng = np.random.default_rng(11)
F = np.float32


# ======================================================================
# per-row primitives shared by BOTH lowerings (as in refbackend.rs,
# where prefill and decode run the same row-wise arithmetic)
# ======================================================================

def rmsnorm_row(x, w, eps):
    ms = (x.astype(F) ** 2).mean()
    r = F(1.0) / np.sqrt(ms + F(eps))
    return (x * r * w).astype(F)


def rope_row(x, pos, heads, dh, theta):
    x = x.reshape(heads, dh).copy()
    half = dh // 2
    freqs = theta ** (-np.arange(half, dtype=F) / F(half))
    ang = F(pos) * freqs
    cos, sin = np.cos(ang).astype(F), np.sin(ang).astype(F)
    x1, x2 = x[:, :half].copy(), x[:, half:].copy()
    x[:, :half] = x1 * cos - x2 * sin
    x[:, half:] = x1 * sin + x2 * cos
    return x.reshape(heads * dh)


def attn_row(q, kbuf, vbuf, pmax, h, kv, dh):
    """One query row against K/V rows [0..pmax] ([npos, kv, dh])."""
    group = h // kv
    scale = F(1.0 / np.sqrt(dh))
    o = np.zeros(h * dh, dtype=F)
    for hi in range(h):
        g = hi // group
        qr = q[hi * dh : (hi + 1) * dh]
        dots = (kbuf[: pmax + 1, g, :] @ qr) * scale
        m = dots.max()
        e = np.exp(dots - m)
        p = (e / e.sum()).astype(F)
        o[hi * dh : (hi + 1) * dh] = (p[:, None] * vbuf[: pmax + 1, g, :]).sum(axis=0)
    return o


def block_row(x, pos, kbuf, vbuf, layer, cfg):
    """One token row through one (GQA attn + FFN) layer, writing its K/V
    at `pos` and attending over [0..pos] — identical arithmetic whether
    the row is part of a prefill window or a decode step."""
    h, dh, eps, theta = cfg["h"], cfg["dh"], cfg["eps"], cfg["theta"]
    kv = layer["kv"]
    hn = rmsnorm_row(x, layer["anorm"], eps)
    q = rope_row((hn @ layer["wq"]).astype(F), pos, h, dh, theta)
    k = rope_row((hn @ layer["wk"]).astype(F), pos, kv, dh, theta)
    v = (hn @ layer["wv"]).astype(F)
    kbuf[pos] = k.reshape(kv, dh)
    vbuf[pos] = v.reshape(kv, dh)
    o = attn_row(q, kbuf, vbuf, pos, h, kv, dh)
    x = (x + (o @ layer["wo"]).astype(F)).astype(F)
    hn = rmsnorm_row(x, layer["fnorm"], eps)
    g = (hn @ layer["wg"]).astype(F)
    u = (hn @ layer["wu"]).astype(F)
    z = (g * (F(1.0) / (F(1.0) + np.exp(-g))) * u).astype(F)
    return (x + (z @ layer["wd"]).astype(F)).astype(F)


def head_row(x, norm, e, eps):
    return (rmsnorm_row(x, norm, eps) @ e.T).astype(F)


def forward_positions(tokens, positions, caches, cfg):
    """Run `tokens` (at `positions`) through the whole model, updating
    each layer's K/V buffers in place; returns the final hidden rows.
    The cold prefill runs this over ALL prompt rows; the hit path runs
    it only over the suffix rows against imported buffers."""
    out = []
    for tok, pos in zip(tokens, positions):
        x = cfg["embed"][tok].copy()
        for layer, (kbuf, vbuf) in zip(cfg["layers"], caches):
            x = block_row(x, pos, kbuf, vbuf, layer, cfg)
        out.append(x)
    return out


def check_hit_equals_miss():
    d, h, dh, vsz = 32, 4, 8, 64
    cfg = {
        "h": h, "dh": dh, "eps": 1e-5, "theta": 10000.0,
        "embed": rng.normal(0, 0.3, (vsz, d)).astype(F),
        "fnorm": rng.normal(0, 0.5, d).astype(F),
        "layers": [],
    }
    for kv in (2, 1):  # per-layer VARIABLE kv-head counts (paper §6)
        i = 48
        cfg["layers"].append({
            "kv": kv,
            "anorm": rng.normal(0, 0.5, d).astype(F),
            "wq": rng.normal(0, 0.2, (d, h * dh)).astype(F),
            "wk": rng.normal(0, 0.2, (d, kv * dh)).astype(F),
            "wv": rng.normal(0, 0.2, (d, kv * dh)).astype(F),
            "wo": rng.normal(0, 0.2, (h * dh, d)).astype(F),
            "fnorm": rng.normal(0, 0.5, d).astype(F),
            "wg": rng.normal(0, 0.2, (d, i)).astype(F),
            "wu": rng.normal(0, 0.2, (d, i)).astype(F),
            "wd": rng.normal(0, 0.2, (i, d)).astype(F),
        })
    smax, P, L = 24, 13, 8  # 13-token prompt, 8-token retained prefix

    prompt = rng.integers(0, vsz, P).tolist()
    fresh = lambda: [
        (np.zeros((smax, lay["kv"], dh), dtype=F), np.zeros((smax, lay["kv"], dh), dtype=F))
        for lay in cfg["layers"]
    ]

    # cold miss: every prompt row computed (the prefill window)
    cold_caches = fresh()
    cold_hidden = forward_positions(prompt, range(P), cold_caches, cfg)
    cold_logits = head_row(cold_hidden[-1], cfg["fnorm"], cfg["embed"], cfg["eps"])

    # retention: export rows [0, L) — bitwise copies (Backend::export_kv)
    seg = [(k[:L].copy(), v[:L].copy()) for (k, v) in cold_caches]

    # hit: import the segment, teacher-force ONLY the suffix
    hit_caches = fresh()
    for (kb, vb), (ks, vs) in zip(hit_caches, seg):
        kb[:L] = ks
        vb[:L] = vs
    hit_hidden = forward_positions(prompt[L:], range(L, P), hit_caches, cfg)
    hit_logits = head_row(hit_hidden[-1], cfg["fnorm"], cfg["embed"], cfg["eps"])

    assert np.array_equal(cold_logits, hit_logits), "hit logits != miss logits"
    for j in range(P - L):
        assert np.array_equal(cold_hidden[L + j], hit_hidden[j]), f"suffix row {j} diverged"
    for (ck, cv), (hk, hv) in zip(cold_caches, hit_caches):
        assert np.array_equal(ck[:P], hk[:P]), "K cache rows diverged"
        assert np.array_equal(cv[:P], hv[:P]), "V cache rows diverged"
    # garbage beyond the import never leaks: poison rows >= P, recompute
    poisoned = fresh()
    for (kb, vb), (ks, vs) in zip(poisoned, seg):
        kb[:L] = ks
        vb[:L] = vs
        kb[P:] = rng.normal(0, 9.0, kb[P:].shape)
        vb[P:] = rng.normal(0, 9.0, vb[P:].shape)
    pois_hidden = forward_positions(prompt[L:], range(L, P), poisoned, cfg)
    assert np.array_equal(pois_hidden[-1], hit_hidden[-1]), "stale rows leaked"
    print("1. cache-hit forward == cold-miss forward, bitwise (logits, hidden, caches) ✓")
    return cfg, cold_caches, prompt


def check_jax_anchor(cfg, prompt):
    try:
        from compile.configs import ModelCfg
        from compile import model as jmodel
        import jax.numpy as jnp
    except ImportError as e:
        print(f"2. SKIPPED (jax unavailable: {e})")
        return
    d, h, dh = 32, cfg["h"], cfg["dh"]
    P = len(prompt)
    lay = cfg["layers"][0]
    jcfg = ModelCfg(
        name="verify", d=d, n_layers=2, n_heads=h, head_dim=dh, i=48, v=64,
        s_train=8, b_train=1, s_prefill=P, b_decode=1, s_max=24, s_long=8,
        rope_theta=cfg["theta"], eps=cfg["eps"],
    )
    # numpy per-row transcription of ONE attn block over the window ...
    kbuf = np.zeros((24, lay["kv"], dh), dtype=F)
    vbuf = np.zeros((24, lay["kv"], dh), dtype=F)
    x0 = cfg["embed"][np.array(prompt)]
    ys = []
    for p in range(P):
        hn = rmsnorm_row(x0[p], lay["anorm"], cfg["eps"])
        q = rope_row((hn @ lay["wq"]).astype(F), p, h, dh, cfg["theta"])
        k = rope_row((hn @ lay["wk"]).astype(F), p, lay["kv"], dh, cfg["theta"])
        kbuf[p] = k.reshape(lay["kv"], dh)
        vbuf[p] = (hn @ lay["wv"]).astype(F).reshape(lay["kv"], dh)
        ys.append(x0[p] + attn_row(q, kbuf, vbuf, p, h, lay["kv"], dh) @ lay["wo"])
    ys = np.stack(ys)[None].astype(F)
    # ... against the JAX prefill oracle
    yj, kj, vj = jmodel.attn_gqa_fwd(
        jcfg, jnp.asarray(x0[None]), jnp.asarray(lay["anorm"]), jnp.asarray(lay["wq"]),
        jnp.asarray(lay["wk"]), jnp.asarray(lay["wv"]), jnp.asarray(lay["wo"]),
    )
    assert np.allclose(ys, np.asarray(yj), atol=2e-5), "attn prefill oracle mismatch"
    assert np.allclose(kbuf[:P], np.asarray(kj)[0], atol=2e-5), "prefill K oracle mismatch"
    assert np.allclose(vbuf[:P], np.asarray(vj)[0], atol=2e-5), "prefill V oracle mismatch"
    # ffn + head rows
    yf = np.stack([
        ys[0, p] + (
            lambda hn: ((hn @ lay["wg"]) * (1.0 / (1.0 + np.exp(-(hn @ lay["wg"]))))
                        * (hn @ lay["wu"])) @ lay["wd"]
        )(rmsnorm_row(ys[0, p], lay["fnorm"], cfg["eps"]))
        for p in range(P)
    ]).astype(F)
    yfj = jmodel.ffn_fwd(jnp.asarray(ys), jnp.asarray(lay["fnorm"]), jnp.asarray(lay["wg"]),
                         jnp.asarray(lay["wu"]), jnp.asarray(lay["wd"]))
    assert np.allclose(yf[None], np.asarray(yfj), atol=2e-5), "ffn oracle mismatch"
    lg = head_row(yf[-1], cfg["fnorm"], cfg["embed"], cfg["eps"])
    lgj = jmodel.head_fwd(jnp.asarray(yf[None, -1:, :]), jnp.asarray(cfg["fnorm"]),
                          jnp.asarray(cfg["embed"]))
    assert np.allclose(lg, np.asarray(lgj)[0, 0], atol=2e-4), "head oracle mismatch"
    print("2. per-row transcription matches the JAX prefill/ffn/head oracles ✓")


# ======================================================================
# 3. radix tree vs brute force (port of serving/prefixcache.rs)
# ======================================================================

def align_down(n, p):
    return (n // p) * p


class PyPrefixCache:
    """Line-for-line port of PrefixCache (tree logic only)."""

    def __init__(self, page_len):
        self.nodes = [{"edge": [], "children": [], "seg": None, "depth": 0, "parent": 0}]
        self.paths = {}  # seg_id -> full token path (for validity checks)
        self.page_len = page_len
        self.next = 1

    def best_match(self, prompt):
        cur, i = 0, 0
        deepest, frontier = None, None
        while True:
            node = self.nodes[cur]
            if node["seg"] is not None and node["depth"] > 0:
                deepest = (node["seg"], node["depth"])
            if i >= len(prompt):
                frontier = node["children"][0] if node["children"] else None
                break
            child = next(
                (c for c in node["children"] if self.nodes[c]["edge"][0] == prompt[i]), None
            )
            if child is None:
                frontier = node["children"][0] if node["children"] else None
                break
            edge = self.nodes[child]["edge"]
            common = 0
            for a, b in zip(edge, prompt[i:]):
                if a != b:
                    break
                common += 1
            i += common
            if common == len(edge):
                cur = child
                continue
            frontier = child
            break
        m = align_down(min(i, len(prompt) - 1), self.page_len)
        if m == 0:
            return None
        if frontier is not None:
            n = frontier
            while True:
                if self.nodes[n]["seg"] is not None:
                    return (self.nodes[n]["seg"], m)
                if not self.nodes[n]["children"]:
                    break
                n = self.nodes[n]["children"][0]
        if deepest is None:
            return None
        return (deepest[0], min(deepest[1], m))

    def covered(self, tokens, length):
        cur, i = 0, 0
        while i < length:
            node = self.nodes[cur]
            child = next(
                (c for c in node["children"] if self.nodes[c]["edge"][0] == tokens[i]), None
            )
            if child is None:
                return False
            edge = self.nodes[child]["edge"]
            common = 0
            for a, b in zip(edge, tokens[i:length]):
                if a != b:
                    break
                common += 1
            i += common
            if common < len(edge):
                return i == length
            cur = child
        return True

    def insert_path(self, tokens):
        cur, i = 0, 0
        while i < len(tokens):
            node = self.nodes[cur]
            child = next(
                (c for c in node["children"] if self.nodes[c]["edge"][0] == tokens[i]), None
            )
            if child is None:
                idx = len(self.nodes)
                self.nodes.append({"edge": list(tokens[i:]), "children": [], "seg": None,
                                   "depth": len(tokens), "parent": cur})
                self.nodes[cur]["children"].append(idx)
                return idx
            edge = self.nodes[child]["edge"]
            common = 0
            for a, b in zip(edge, tokens[i:]):
                if a != b:
                    break
                common += 1
            if common == len(edge):
                cur = child
                i += common
                continue
            mid = len(self.nodes)
            self.nodes.append({"edge": edge[:common], "children": [child], "seg": None,
                               "depth": self.nodes[cur]["depth"] + common, "parent": cur})
            pos = self.nodes[cur]["children"].index(child)
            self.nodes[cur]["children"][pos] = mid
            self.nodes[child]["edge"] = edge[common:]
            self.nodes[child]["parent"] = mid
            if i + common == len(tokens):
                return mid
            leaf = len(self.nodes)
            self.nodes.append({"edge": list(tokens[i + common:]), "children": [], "seg": None,
                               "depth": len(tokens), "parent": mid})
            self.nodes[mid]["children"].append(leaf)
            return leaf
        return cur

    def insert(self, tokens, seg_len):
        assert seg_len % self.page_len == 0 and 0 < seg_len <= len(tokens)
        node = self.insert_path(tokens[:seg_len])
        assert self.nodes[node]["seg"] is None, "caller deduplicates"
        sid = self.next
        self.next += 1
        self.nodes[node]["seg"] = sid
        self.paths[sid] = list(tokens[:seg_len])
        return sid, node

    def remove(self, sid, node):
        del self.paths[sid]
        self.nodes[node]["seg"] = None
        cur = node
        while (cur != 0 and self.nodes[cur]["seg"] is None
               and not self.nodes[cur]["children"]):
            parent = self.nodes[cur]["parent"]
            self.nodes[parent]["children"].remove(cur)
            cur = parent


def common_len(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def check_radix_fuzz():
    trials, lookups = 0, 0
    for page in (2, 4):
        for case in range(400):
            r = np.random.default_rng(1000 + case + page)
            cache = PyPrefixCache(page)
            nodes_of = {}
            alphabet = 4
            for _ in range(r.integers(1, 7)):
                ln = int(r.integers(1, 5)) * page
                path = [int(t) for t in r.integers(0, alphabet, ln + int(r.integers(0, 3)))]
                if len(path) < ln:
                    continue
                if cache.covered(path, ln):
                    continue  # engine dedupes exactly like this
                sid, node = cache.insert(path, ln)
                nodes_of[sid] = node
                trials += 1
            for _ in range(8):
                prompt = [int(t) for t in r.integers(0, alphabet, int(r.integers(1, 14)))]
                got = cache.best_match(prompt)
                # brute-force oracle over the retained paths
                want = 0
                for path in cache.paths.values():
                    c = common_len(path, prompt)
                    want = max(want, align_down(min(c, len(prompt) - 1), page))
                if want == 0:
                    assert got is None, f"page {page}: expected no hit, got {got}"
                else:
                    assert got is not None, f"page {page}: missed a {want}-token hit for {prompt}"
                    sid, ln = got
                    assert ln == want, f"page {page}: hit {ln} != best {want} for {prompt}"
                    # validity: the chosen segment really shares ln tokens
                    assert common_len(cache.paths[sid], prompt) >= ln, "invalid segment chosen"
                # covered == some path contains tokens[:L]
                lmax = min(len(prompt), 8)
                if lmax >= 1:
                    lchk = int(r.integers(1, lmax + 1))
                    want_cov = any(
                        common_len(p, prompt) >= lchk for p in cache.paths.values()
                    )
                    assert cache.covered(prompt, lchk) == want_cov, "covered() disagrees"
                lookups += 1
            # removals keep the survivors intact
            for sid in list(cache.paths):
                if r.random() < 0.5:
                    cache.remove(sid, nodes_of[sid])
            for sid, path in cache.paths.items():
                probe = path + [99]
                got = cache.best_match(probe)
                assert got is not None and got[1] == align_down(len(path), page), \
                    "survivor lost after pruning"
    print(f"3. radix tree == brute-force oracle ({trials} inserts, {lookups} lookups) ✓")


# ======================================================================
# 4. shared-page accounting (port of PagedKvManager's shared segments)
# ======================================================================

class PyPaged:
    def __init__(self, kv_heads, head_dim, page_len, budget):
        self.kv = kv_heads
        self.dh = head_dim
        self.page_len = page_len
        self.budget = budget
        self.allocated = 0
        self.seqs = {}
        self.shared = {}

    def page_bytes(self, l):
        return 2 * self.kv[l] * self.dh * self.page_len * 4

    def pages_for(self, positions):
        return -(-positions // self.page_len)

    def bytes_for_new(self, total, shared_positions):
        t = self.pages_for(total)
        s = min(self.pages_for(shared_positions), t)
        return sum((t - s) * self.page_bytes(l) for l in range(len(self.kv)) if self.kv[l])

    def shared_bytes(self, positions):
        p = self.pages_for(positions)
        return sum(p * self.page_bytes(l) for l in range(len(self.kv)) if self.kv[l])

    def retain(self, sid, positions):
        if sid in self.shared:
            return False
        b = self.shared_bytes(positions)
        if self.allocated + b > self.budget:
            return False
        self.allocated += b
        self.shared[sid] = {"pages": self.pages_for(positions), "refs": 0, "bytes": b}
        return True

    def evict(self, sid):
        s = self.shared.get(sid)
        if s is None or s["refs"]:
            return False
        self.allocated -= s["bytes"]
        del self.shared[sid]
        return True

    def admit(self, qid, positions, sid=None, shared_positions=0):
        if qid in self.seqs:
            return False
        if sid is not None and sid not in self.shared:
            return False
        grow = self.bytes_for_new(positions, shared_positions)
        if self.allocated + grow > self.budget:
            return False
        self.allocated += grow
        if sid is not None:
            self.shared[sid]["refs"] += 1
        t = self.pages_for(positions)
        self.seqs[qid] = {
            "per_layer": [t if self.kv[l] else 0 for l in range(len(self.kv))],
            "positions": positions,
            "shared": self.pages_for(shared_positions) if sid is not None else 0,
            "seg": sid,
        }
        return True

    def grow(self, qid):
        s = self.seqs.get(qid)
        if s is None:
            return False
        new_pos = s["positions"] + 1
        t = self.pages_for(new_pos)
        g = sum(
            max(t - max(s["per_layer"][l], s["shared"]), 0) * self.page_bytes(l)
            for l in range(len(self.kv)) if self.kv[l]
        )
        if self.allocated + g > self.budget:
            return False
        self.allocated += g
        for l in range(len(self.kv)):
            if self.kv[l]:
                s["per_layer"][l] = t
        s["positions"] = new_pos
        return True

    def truncate(self, qid, new_len):
        if new_len == 0:
            return self.release(qid)
        s = self.seqs.get(qid)
        if s is None or new_len >= s["positions"]:
            return
        t = self.pages_for(new_len)
        freed = 0
        for l in range(len(self.kv)):
            keep = min(t, s["per_layer"][l])
            freed += (max(s["per_layer"][l] - s["shared"], 0)
                      - max(keep - s["shared"], 0)) * self.page_bytes(l)
            s["per_layer"][l] = keep
        s["positions"] = new_len
        self.allocated -= freed

    def release(self, qid):
        s = self.seqs.pop(qid, None)
        if s is None:
            return
        self.allocated -= sum(
            max(s["per_layer"][l] - s["shared"], 0) * self.page_bytes(l)
            for l in range(len(self.kv))
        )
        if s["seg"] is not None and s["seg"] in self.shared:
            self.shared[s["seg"]]["refs"] -= 1

    def check(self):
        want = sum(s["bytes"] for s in self.shared.values())
        for s in self.seqs.values():
            want += sum(
                max(s["per_layer"][l] - s["shared"], 0) * self.page_bytes(l)
                for l in range(len(self.kv))
            )
        assert self.allocated == want, f"accounting drift: {self.allocated} != {want}"
        assert 0 <= self.allocated <= self.budget
        for sid, seg in self.shared.items():
            live = sum(1 for s in self.seqs.values() if s["seg"] == sid)
            assert seg["refs"] == live, f"seg {sid}: refs {seg['refs']} != live {live}"


def check_accounting_fuzz():
    ops = 0
    for case in range(250):
        r = np.random.default_rng(5000 + case)
        kv = [int(k) for k in r.choice([0, 1, 2, 4], size=int(r.integers(1, 4)))]
        if not any(kv):
            kv[0] = 2
        pg = PyPaged(kv, 8, int(r.choice([4, 8, 16])), int(r.integers(1, 40)) * 4096)
        next_seq, next_seg = 1, 100
        for _ in range(60):
            op = r.random()
            ops += 1
            if op < 0.2:
                pg.retain(next_seg, int(r.integers(1, 40)))
                next_seg += 1
            elif op < 0.45:
                segs = [s for s, v in pg.shared.items()]
                if segs and r.random() < 0.6:
                    sid = int(r.choice(segs))
                    sp = min(int(r.integers(0, 40)), pg.shared[sid]["pages"] * pg.page_len)
                    pg.admit(next_seq, sp + int(r.integers(0, 20)), sid, sp)
                else:
                    pg.admit(next_seq, int(r.integers(1, 40)))
                next_seq += 1
                # duplicate admits must be refused without drift
                if pg.seqs:
                    qid = int(r.choice(list(pg.seqs)))
                    assert not pg.admit(qid, 8), "duplicate admit accepted"
            elif op < 0.65 and pg.seqs:
                pg.grow(int(r.choice(list(pg.seqs))))
            elif op < 0.8 and pg.seqs:
                qid = int(r.choice(list(pg.seqs)))
                pg.truncate(qid, int(r.integers(0, pg.seqs[qid]["positions"] + 2)))
            elif op < 0.9 and pg.seqs:
                pg.release(int(r.choice(list(pg.seqs))))
            elif pg.shared:
                sid = int(r.choice(list(pg.shared)))
                before_refs = pg.shared[sid]["refs"]
                evicted = pg.evict(sid)
                assert evicted == (before_refs == 0), "eviction broke a live reference"
            pg.check()
        for qid in list(pg.seqs):
            pg.release(qid)
        for sid in list(pg.shared):
            assert pg.evict(sid)
        pg.check()
        assert pg.allocated == 0, "pool did not drain to zero"
    print(f"4. shared-page accounting exact under {ops} random ops (drains to zero) ✓")


if __name__ == "__main__":
    cfg, caches, prompt = check_hit_equals_miss()
    check_jax_anchor(cfg, prompt)
    check_radix_fuzz()
    check_accounting_fuzz()
    print("all prefix-cache cross-checks passed")
