#!/usr/bin/env python3
"""Insert rustdoc lines above given 1-based line numbers.

Driven by per-file dicts in docs specs: `python3 insert_docs.py <file> <spec.py>`
where spec.py defines DOCS = {line_no: "one line" or ["multi", "line"]}.
Indent is copied from the target line. Inserts bottom-up so numbers stay valid.
"""
import sys


def apply(path, docs):
    lines = open(path).read().splitlines(keepends=True)
    for ln in sorted(docs, reverse=True):
        target = lines[ln - 1]
        indent = target[: len(target) - len(target.lstrip())]
        text = docs[ln]
        if isinstance(text, str):
            text = [text]
        ins = "".join(f"{indent}/// {t}\n" if t else f"{indent}///\n" for t in text)
        lines.insert(ln - 1, ins)
    open(path, "w").write("".join(lines))


if __name__ == "__main__":
    spec = {}
    exec(open(sys.argv[2]).read(), spec)
    apply(sys.argv[1], spec["DOCS"])
    print(f"inserted {len(spec['DOCS'])} doc blocks into {sys.argv[1]}")
