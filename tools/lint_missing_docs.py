#!/usr/bin/env python3
"""Heuristic pre-flight for rustc's `missing_docs` lint (no cargo in this
container): flags public items, public struct fields, and public-enum
variants that lack a doc comment or #[doc] attribute directly above.
Over-approximates (pub items in private modules are flagged too); trait
impls and `pub use` re-exports are skipped, matching the real lint.
"""
import re
import sys
from pathlib import Path

ITEM = re.compile(r"^(\s*)pub (fn|struct|enum|trait|type|const|static|unsafe fn) ")
FIELD = re.compile(r"^(\s+)pub [a-zA-Z_][a-zA-Z0-9_]*\s*:")
VARIANT = re.compile(r"^(\s+)(?:#\[[^\]]*\]\s*)?[A-Z][A-Za-z0-9_]*(\s*\{|\s*\(|\s*,|\s*$|\s*=)")
MACRO = re.compile(r"^\s*macro_rules!\s")


def has_doc(lines, i):
    j = i - 1
    while j >= 0:
        t = lines[j].strip()
        if t.startswith("///") or t.startswith("#[doc") or t.endswith("*/"):
            return True
        if t.startswith("#[") or t.startswith("#!["):  # other attrs: keep looking up
            j -= 1
            continue
        if t == "":
            return False
        return False
    return False


def scan(path):
    lines = path.read_text().splitlines()
    out = []
    enum_depth = None  # indentation depth inside a pub enum body
    brace = 0
    in_tests = False
    test_depth = 0
    exported_macro = False
    for i, line in enumerate(lines):
        stripped = line.strip()
        if stripped.startswith("#[cfg(test)]"):
            in_tests = True
            test_depth = brace
        if in_tests and brace < test_depth and stripped.startswith("}"):
            in_tests = False
        opens = line.count("{") - line.count("}")
        if not in_tests:
            if stripped.startswith("#[macro_export]"):
                exported_macro = True
            elif MACRO.match(line) and exported_macro:
                if not has_doc(lines, i):
                    out.append((i + 1, "macro", stripped[:70]))
                exported_macro = False
            m = ITEM.match(line)
            if m and "pub use" not in line:
                if not has_doc(lines, i):
                    out.append((i + 1, m.group(2), stripped[:70]))
                if m.group(2) == "enum" and "{" in line and "}" not in line:
                    enum_depth = brace
            elif enum_depth is not None and brace == enum_depth + 1:
                if FIELD.match(line) or VARIANT.match(line):
                    if not has_doc(lines, i):
                        out.append((i + 1, "variant", stripped[:70]))
            elif FIELD.match(line) and enum_depth is None and brace >= 1:
                if not has_doc(lines, i):
                    out.append((i + 1, "field", stripped[:70]))
        brace += opens
        if enum_depth is not None and brace <= enum_depth:
            enum_depth = None
    return out


def main():
    root = Path(sys.argv[1] if len(sys.argv) > 1 else "rust/src")
    total = 0
    for p in sorted(root.rglob("*.rs")):
        if p.name in ("main.rs", "literal.rs", "registry.rs", "xla_backend.rs"):
            # bin crate / pjrt-feature-gated: not in the default docs build
            continue
        found = scan(p)
        if found:
            print(f"== {p} ({len(found)})")
            for ln, kind, text in found:
                print(f"  {ln:5} {kind:8} {text}")
            total += len(found)
    print(f"TOTAL {total}")
    return 0 if total == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
