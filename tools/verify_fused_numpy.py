#!/usr/bin/env python3
"""Toolchain-free cross-check of the fused multi-token decode kernel.

The growth container has no cargo, so this transcribes the two Rust
lowerings of the speculative verify pass into numpy, loop-for-loop:

  * `seq_step`   — rust `attn_gqa_decode` (one decode step per token)
  * `fused_pass` — rust `attn_gqa_decode_fused` (one pass over m tokens)

and checks, on random inputs:

  1. fused == m sequential steps, exactly (same arithmetic per row, same
     accumulation order — the bitwise-equivalence claim of DESIGN.md §6),
     including ragged lanes with parked padding;
  2. the sequential transcription matches the independent JAX oracle
     `python/compile/model.py::attn_gqa_decode` to float32 tolerance
     (anchors the transcription itself);
  3. lane isolation: garbage in cache rows past a lane's committed
     length never changes any output (the masking/deadness rule).

Run: PYTHONPATH=python python3 tools/verify_fused_numpy.py
"""
import numpy as np

rng = np.random.default_rng(7)
F = np.float32


def rmsnorm(x, w, eps):  # rows of d
    ms = (x.astype(F) ** 2).mean(axis=-1, keepdims=True)
    r = 1.0 / np.sqrt(ms + F(eps))
    return (x * r * w).astype(F)


def rope(x, positions, theta):  # x [rows, heads, dh], positions [rows]
    rows, heads, dh = x.shape
    half = dh // 2
    freqs = theta ** (-np.arange(half, dtype=F) / F(half))
    ang = positions.astype(F)[:, None, None] * freqs  # [rows,1,half]
    cos, sin = np.cos(ang).astype(F), np.sin(ang).astype(F)
    x1, x2 = x[..., :half], x[..., half:]
    return np.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(F)


def softmax_row(q_row, kc_lane, pmax, scale):
    # q_row [dh], kc_lane [smax, dh] for one kv group
    dots = (kc_lane[: pmax + 1] @ q_row) * F(scale)
    m = dots.max()
    e = np.exp(dots - m)
    return (e / e.sum()).astype(F)


def attn_over_cache(qf, kc, vc, pos_row, b_index, h, kv, dh):
    # qf [heads*dh] for one row; returns o [h*dh]
    group = h // kv
    scale = 1.0 / np.sqrt(F(dh))
    o = np.zeros(h * dh, dtype=F)
    for hi in range(h):
        g = hi // group
        q_row = qf[hi * dh : (hi + 1) * dh]
        p = softmax_row(q_row, kc[b_index, :, g, :], pos_row, scale)
        o[hi * dh : (hi + 1) * dh] = (p[:, None] * vc[b_index, : pos_row + 1, g, :]).sum(axis=0)
    return o


def seq_step(cfg, x, kc, vc, pos, w):
    """rust attn_gqa_decode: x [b,1,d], caches [b,smax,kv,dh], pos [b]."""
    h, dh, kv, eps, theta = cfg
    b, _, d = x.shape
    smax = kc.shape[1]
    hn = rmsnorm(x.reshape(b, d), w["norm"], eps)
    qf = rope((hn @ w["wq"]).reshape(b, h, dh), pos, theta)
    kf = rope((hn @ w["wk"]).reshape(b, kv, dh), pos, theta)
    vf = (hn @ w["wv"]).reshape(b, kv, dh)
    kc2, vc2 = kc.copy(), vc.copy()
    for bi in range(b):
        p = int(pos[bi])
        assert p < smax, "sequential path bails at the horizon"
        kc2[bi, p] = kf[bi]
        vc2[bi, p] = vf[bi]
    y = np.empty((b, h * dh), dtype=F)
    for bi in range(b):
        y[bi] = attn_over_cache(qf[bi].reshape(h * dh), kc2, vc2, int(pos[bi]), bi, h, kv, dh)
    out = x.reshape(b, d) + y @ w["wo"]
    return out.astype(F).reshape(b, 1, d), kc2, vc2


def fused_pass(cfg, x, kc, vc, pos, w):
    """rust attn_gqa_decode_fused: x [b,m,d], pos [b] = first new position."""
    h, dh, kv, eps, theta = cfg
    b, m, d = x.shape
    smax = kc.shape[1]
    t = b * m
    hn = rmsnorm(x.reshape(t, d), w["norm"], eps)
    positions = np.array([int(pos[r // m]) + r % m for r in range(t)], dtype=np.int64)
    qf = rope((hn @ w["wq"]).reshape(t, h, dh), positions, theta)
    kf = rope((hn @ w["wk"]).reshape(t, kv, dh), positions, theta)
    vf = (hn @ w["wv"]).reshape(t, kv, dh)
    kc2, vc2 = kc.copy(), vc.copy()
    for bi in range(b):
        for j in range(m):
            p = int(pos[bi]) + j
            if p >= smax:
                continue  # padded/parked overflow: dropped, never read
            kc2[bi, p] = kf[bi * m + j]
            vc2[bi, p] = vf[bi * m + j]
    y = np.empty((t, h * dh), dtype=F)
    for bi in range(b):
        for j in range(m):
            pmax = min(int(pos[bi]) + j, smax - 1)
            y[bi * m + j] = attn_over_cache(
                qf[bi * m + j].reshape(h * dh), kc2, vc2, pmax, bi, h, kv, dh
            )
    out = x.reshape(t, d) + y @ w["wo"]
    return out.astype(F).reshape(b, m, d), kc2, vc2


def main():
    h, dh, kv, eps, theta = 4, 8, 2, 1e-5, 10000.0
    cfg = (h, dh, kv, eps, theta)
    b, smax, d = 2, 24, 32
    w = {
        "norm": rng.normal(0, 0.5, d).astype(F),
        "wq": rng.normal(0, 0.2, (d, h * dh)).astype(F),
        "wk": rng.normal(0, 0.2, (d, kv * dh)).astype(F),
        "wv": rng.normal(0, 0.2, (d, kv * dh)).astype(F),
        "wo": rng.normal(0, 0.2, (h * dh, d)).astype(F),
    }
    # committed prefixes: lane 0 holds 6 positions, lane 1 holds 3
    kc = rng.normal(0, 0.3, (b, smax, kv, dh)).astype(F)
    vc = rng.normal(0, 0.3, (b, smax, kv, dh)).astype(F)
    committed = [6, 3]
    m = 5  # lane 0 feeds 5 real tokens; lane 1 feeds 3 real + 2 padded
    real = [5, 3]
    x = rng.normal(0, 0.5, (b, m, d)).astype(F)
    pos = np.array(committed, dtype=np.int64)

    # --- 1. fused == sequential, exactly, on all real rows + cache ---
    yf, kcf, vcf = fused_pass(cfg, x, kc, vc, pos, w)
    kcs, vcs = kc, vc
    ys = np.empty_like(yf)
    for j in range(m):
        # sequential lowering: at step j a lane past its feed is parked at
        # its own frontier (dummy token 0 -> here: its own x row is fed to
        # a dead position; the engine feeds token 0, but ANY values work
        # since the row is discarded — use the same x for exactness)
        xj = x[:, j : j + 1, :]
        pj = np.array(
            [committed[i] + min(j, real[i]) for i in range(b)], dtype=np.int64
        )
        yj, kcs, vcs = seq_step(cfg, xj, kcs, vcs, pj, w)
        ys[:, j, :] = yj[:, 0, :]
    for i in range(b):
        r = real[i]
        assert np.array_equal(yf[i, :r], ys[i, :r]), f"lane {i}: fused != sequential"
        tot = committed[i] + r
        assert np.array_equal(kcf[i, :tot], kcs[i, :tot]), f"lane {i}: K cache diverged"
        assert np.array_equal(vcf[i, :tot], vcs[i, :tot]), f"lane {i}: V cache diverged"
    print("1. fused == sequential on every real row and cache position (exact) ✓")

    # --- 2. anchor the sequential transcription to the JAX oracle ---
    try:
        from compile.configs import ModelCfg
        from compile import model as jmodel
        import jax.numpy as jnp

        jcfg = ModelCfg(
            name="verify", d=d, n_layers=1, n_heads=h, head_dim=dh, i=64, v=64,
            s_train=8, b_train=1, s_prefill=8, b_decode=b, s_max=smax, s_long=8,
            rope_theta=theta, eps=eps,
        )
        xj = x[:, 0:1, :]
        yj_np, kc1, vc1 = seq_step(cfg, xj, kc, vc, pos, w)
        yj, kcj, vcj = jmodel.attn_gqa_decode(
            jcfg, jnp.asarray(xj), jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(pos, dtype=jnp.int32), jnp.asarray(w["norm"]),
            jnp.asarray(w["wq"]), jnp.asarray(w["wk"]), jnp.asarray(w["wv"]),
            jnp.asarray(w["wo"]),
        )
        assert np.allclose(yj_np, np.asarray(yj), atol=2e-5), "JAX oracle mismatch (y)"
        assert np.allclose(kc1, np.asarray(kcj), atol=2e-5), "JAX oracle mismatch (K)"
        assert np.allclose(vc1, np.asarray(vcj), atol=2e-5), "JAX oracle mismatch (V)"
        print("2. sequential transcription matches the JAX attn_gqa_decode oracle ✓")
    except ImportError as e:
        print(f"2. SKIPPED (jax unavailable: {e})")

    # --- 3. deadness: garbage past the committed length changes nothing ---
    kc_g, vc_g = kc.copy(), vc.copy()
    for i in range(b):
        kc_g[i, committed[i] :] = rng.normal(0, 9.0, (smax - committed[i], kv, dh))
        vc_g[i, committed[i] :] = rng.normal(0, 9.0, (smax - committed[i], kv, dh))
    yg, kcg2, _ = fused_pass(cfg, x, kc_g, vc_g, pos, w)
    for i in range(b):
        r = real[i]
        assert np.array_equal(yg[i, :r], yf[i, :r]), f"lane {i}: stale rows leaked into output"
        tot = committed[i] + r
        assert np.array_equal(kcg2[i, :tot], kcf[i, :tot])
    print("3. rows past the committed length are dead (parking isolation holds) ✓")
    print("all fused-decode cross-checks passed")


if __name__ == "__main__":
    main()
