#!/usr/bin/env python3
"""Toolchain-free cross-check of the fleet-tracing stack (DESIGN.md §13).

Three parts, stdlib only:

1. A transcription of `merge_fleet` / `emit_log_tracks` (rust/src/obs/
   export.rs) and `request_spans` / `merge_logs` (rust/src/obs/trace.rs)
   builds the export.rs unit tests' two-replica `sample_fleet` scenario
   (plus one engine-step record so the validator's liveness check is
   satisfiable) and replays the Rust tests' structural expectations
   against the generated document, plus exact numeric anchors for the
   stitched pid-0 tracks.
2. `verify_trace.py --fleet` self-test: the generated document must be
   accepted (with --expect-prefix-hit and --expect-migration), and ten
   targeted corruptions must each be rejected with the *intended*
   diagnostic, not an incidental one.
3. A transcription of `obs::slo` (fold_requests, burn_rates,
   burn_profiles) replays every slo.rs unit-test expectation, pins the
   window boundary semantics (`finish == now - window` excluded,
   `finish == now` included), and fuzzes fold_requests against directly
   generated request boundaries over 5 seeds.

Exit 0 and a summary on success; the first mismatch raises.
"""

import copy
import json
import os
import random
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
VERIFY = os.path.join(HERE, "verify_trace.py")

TICK = 1000  # TICK_US
TID_REQ_BASE = 1000
REPLICA_SHIFT = 48

# ---------------------------------------------------------------------------
# trace.rs transcription: request_spans / merge_logs over (ts, ev-dict) recs
# ---------------------------------------------------------------------------

LIFE_EVS = ("submitted", "admitted", "first_token", "finished", "routed")


def request_spans(recs):
    order, spans = [], {}
    for ts, ev in recs:
        if ev["ev"] not in LIFE_EVS:
            continue
        rid = ev["id"]
        if rid not in spans:
            order.append(rid)
            spans[rid] = {
                "id": rid, "route_us": None, "replica": None, "submit_us": ts,
                "admit_us": None, "first_us": None, "finish_us": None,
                "lane": None, "hit": False, "matched": 0, "reason": None,
                "tokens": 0,
            }
        s = spans[rid]
        k = ev["ev"]
        if k == "submitted":
            s["submit_us"] = ts
        elif k == "routed":
            s["route_us"] = ts
            s["replica"] = ev["replica"]
        elif k == "admitted":
            s["admit_us"] = ts
            s["lane"] = ev["lane"]
            s["hit"] = ev["hit"]
            s["matched"] = ev["matched"]
        elif k == "first_token":
            if s["first_us"] is None:
                s["first_us"] = ts
        elif k == "finished":
            s["finish_us"] = ts
            s["reason"] = ev["reason"]
            s["tokens"] = ev["tokens"]
    return [spans[i] for i in order]


def merge_logs(rings):
    recs = [r for ring in rings for r in ring]
    recs.sort(key=lambda r: r[0])  # python sort is stable: ring order on ties
    return recs


# ---------------------------------------------------------------------------
# export.rs transcription: merge_fleet / emit_log_tracks
# ---------------------------------------------------------------------------

def ev_base(name, ph, ts, pid, tid):
    return {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}


def complete(name, ts, dur, pid, tid, args):
    e = ev_base(name, "X", ts, pid, tid)
    e["dur"] = dur
    e["args"] = args
    return e


def instant(name, ts, pid, tid, args):
    e = ev_base(name, "i", ts, pid, tid)
    e["s"] = "t"
    e["args"] = args
    return e


def thread_name(pid, tid, name):
    e = ev_base("thread_name", "M", 0, pid, tid)
    e["args"] = {"name": name}
    return e


def process_name(pid, name):
    e = ev_base("process_name", "M", 0, pid, 0)
    e["args"] = {"name": name}
    return e


def emit_log_tracks(events, recs, dropped, pid, t0):
    rb = lambda ts: max(ts - t0, 0)
    last_ts = max((rb(ts) for ts, _ in recs), default=0)
    lanes = []
    for _, ev in recs:
        if ev["ev"] in ("prefill_chunk", "spec_round") and ev["lane"] not in lanes:
            lanes.append(ev["lane"])
    lanes.sort()
    spans = request_spans(recs)

    events.append(thread_name(pid, 0, "engine steps"))
    for l in lanes:
        events.append(thread_name(pid, 100 + l, f"lane{l}"))
    for s in spans:
        events.append(thread_name(pid, TID_REQ_BASE + s["id"], f"req{s['id']}"))

    engine = []
    if dropped > 0:
        engine.append((0, 1, instant("ring_dropped", 0, pid, 0, {"count": dropped})))
    for ts, ev in recs:
        if ev["ev"] == "step":
            engine.append((rb(ts), 0, complete(
                "step", rb(ts), max(ev["dur_us"], 1), pid, 0,
                {"step": ev["step"], "active": ev["active"], "queued": ev["queued"]})))
        elif ev["ev"] == "rejected":
            engine.append((rb(ts), 1, instant(
                "rejected", rb(ts), pid, 0, {"id": ev["id"], "cause": ev["cause"]})))
    engine.sort(key=lambda t: (t[0], t[1]))
    events.extend(e for _, _, e in engine)

    for l in lanes:
        for ts, ev in recs:
            if ev["ev"] == "prefill_chunk" and ev["lane"] == l:
                events.append(instant("prefill_chunk", rb(ts), pid, 100 + l,
                                      {"id": ev["id"], "tokens": ev["tokens"]}))
            elif ev["ev"] == "spec_round" and ev["lane"] == l:
                events.append(instant("spec_round", rb(ts), pid, 100 + l,
                                      {"id": ev["id"], "drafted": ev["drafted"],
                                       "accepted": ev["accepted"],
                                       "rolled_back": ev["rolled_back"]}))

    for s in spans:
        tid = TID_REQ_BASE + s["id"]
        submit = rb(s["submit_us"])
        end = max(rb(s["finish_us"]) if s["finish_us"] is not None else last_ts, submit)
        args = {"id": s["id"], "hit": s["hit"], "matched": s["matched"],
                "tokens": s["tokens"]}
        if s["reason"] is not None:
            args["reason"] = s["reason"]
        events.append(complete("request", submit, end - submit, pid, tid, args))
        if s["admit_us"] is not None:
            a = rb(s["admit_us"])
            events.append(complete("queued", submit, a - submit, pid, tid, {}))
            if s["first_us"] is not None:
                f = rb(s["first_us"])
                events.append(complete("prefill", a, f - a, pid, tid, {}))
                if s["finish_us"] is not None:
                    e = rb(s["finish_us"])
                    events.append(complete("decode", f, e - f, pid, tid, {}))


def merge_fleet(router, replicas, router_dropped=0):
    all_ts = [ts for ts, _ in router] + [ts for ring in replicas for ts, _ in ring]
    t0 = min(all_ts, default=0)
    rb = lambda ts: max(ts - t0, 0)
    events = [process_name(0, "puzzle-router")]
    for r in range(len(replicas)):
        events.append(process_name(r + 1, f"puzzle-replica-{r}"))
    events.append(thread_name(0, 0, "routing"))

    if router_dropped > 0:
        events.append(instant("ring_dropped", 0, 0, 0, {"count": router_dropped}))
    line = []
    for ts, ev in router:
        if ev["ev"] == "routed":
            line.append((rb(ts), instant("routed", rb(ts), 0, 0, {
                "id": ev["id"], "replica": ev["replica"], "matched": ev["matched"],
                "depth": ev["depth"], "reason": ev["reason"],
                "probes": " ".join(f"{m}/{d}" for m, d in ev["probes"])})))
        elif ev["ev"] == "router_shed":
            line.append((rb(ts), instant("router_shed", rb(ts), 0, 0,
                                         {"replicas": ev["replicas"]})))
        elif ev["ev"] == "probe_round":
            line.append((rb(ts), instant("probe_round", rb(ts), 0, 0,
                                         {"probed": ev["probed"], "cached": ev["cached"]})))
    line.sort(key=lambda t: t[0])
    events.extend(e for _, e in line)

    begins, migrations = {}, []
    for ts, ev in router:
        if ev["ev"] == "migration_begin":
            begins[ev["mig"]] = rb(ts)
        elif ev["ev"] == "migration_end":
            if ev["mig"] not in begins:
                continue
            start = begins.pop(ev["mig"])
            migrations.append((start, complete("migration", start, rb(ts) - start, 0, 1, {
                "mig": ev["mig"], "src": ev["src"], "dst": ev["dst"], "seg": ev["seg"],
                "tokens": ev["tokens"], "adopted": ev["adopted"]})))
    for mig, ts in sorted(begins.items()):
        migrations.append((ts, instant("migration_unpaired", ts, 0, 1, {"mig": mig})))
    if migrations:
        events.append(thread_name(0, 1, "migrations"))
        migrations.sort(key=lambda t: t[0])
        events.extend(e for _, e in migrations)

    merged = merge_logs([router] + replicas)
    last_ts = max((rb(ts) for ts, _ in merged), default=0)
    for s in request_spans(merged):
        if s["route_us"] is None:
            continue
        route = rb(s["route_us"])
        tid = TID_REQ_BASE + s["id"]
        events.append(thread_name(0, tid, f"req{s['id']}"))
        end = max(rb(s["finish_us"]) if s["finish_us"] is not None else last_ts, route)
        args = {"id": s["id"], "replica": s["replica"] or 0, "hit": s["hit"],
                "matched": s["matched"], "tokens": s["tokens"]}
        if s["reason"] is not None:
            args["reason"] = s["reason"]
        events.append(complete("request", route, end - route, 0, tid, args))
        submit = rb(s["submit_us"])
        events.append(complete("placement", route, submit - route, 0, tid, {}))
        if s["admit_us"] is not None:
            a = rb(s["admit_us"])
            events.append(complete("queued", submit, a - submit, 0, tid, {}))
            if s["first_us"] is not None:
                f = rb(s["first_us"])
                events.append(complete("prefill", a, f - a, 0, tid, {}))
                if s["finish_us"] is not None:
                    e = rb(s["finish_us"])
                    events.append(complete("decode", f, e - f, 0, tid, {}))

    for r, ring in enumerate(replicas):
        emit_log_tracks(events, ring, 0, r + 1, t0)
    return {"displayTimeUnit": "ms", "traceEvents": events}


# ---------------------------------------------------------------------------
# the export.rs sample_fleet scenario (+ one step record for liveness)
# ---------------------------------------------------------------------------

GID_B = (1 << REPLICA_SHIFT) | 1


def sample_fleet():
    router = [
        (0, {"ev": "probe_round", "probed": 2, "cached": 0}),
        (0, {"ev": "routed", "id": 1, "replica": 0, "matched": 0, "depth": 0,
             "reason": "load", "probes": [(0, 0), (0, 0)]}),
        (6 * TICK, {"ev": "probe_round", "probed": 2, "cached": 0}),
        (6 * TICK, {"ev": "migration_begin", "mig": 1, "src": 0, "dst": 1}),
        (7 * TICK, {"ev": "migration_end", "mig": 1, "src": 0, "dst": 1,
                    "seg": 3, "tokens": 4, "adopted": True}),
        (7 * TICK, {"ev": "routed", "id": GID_B, "replica": 1, "matched": 4,
                    "depth": 0, "reason": "spill", "probes": [(4, 9), (0, 0)]}),
    ]
    replica0 = [
        (1 * TICK, {"ev": "submitted", "id": 1, "prompt": 4, "max_new": 4}),
        (2 * TICK, {"ev": "admitted", "id": 1, "lane": 0, "hit": False, "matched": 0}),
        (3 * TICK, {"ev": "first_token", "id": 1}),
        (3 * TICK, {"ev": "step", "step": 0, "active": 1, "queued": 0, "dur_us": 0}),
        (5 * TICK, {"ev": "finished", "id": 1, "reason": "eos", "tokens": 4}),
    ]
    replica1 = [
        (8 * TICK, {"ev": "submitted", "id": GID_B, "prompt": 6, "max_new": 2}),
        (8 * TICK, {"ev": "admitted", "id": GID_B, "lane": 0, "hit": True, "matched": 4}),
        (9 * TICK, {"ev": "first_token", "id": GID_B}),
        (10 * TICK, {"ev": "finished", "id": GID_B, "reason": "length", "tokens": 2}),
    ]
    return router, [replica0, replica1]


def check_anchors(doc):
    """Replay merge_fleet_stitches_and_tiles_routed_lifecycles plus exact
    numeric anchors for the stitched tracks."""
    evs = doc["traceEvents"]
    pnames = {e["pid"]: e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert pnames == {0: "puzzle-router", 1: "puzzle-replica-0", 2: "puzzle-replica-1"}

    pid0_reqs = [e for e in evs if e["pid"] == 0 and e["name"] == "request"]
    assert len(pid0_reqs) == 2, "both routed requests get fleet tracks"
    for req in pid0_reqs:
        tid = req["tid"]
        kids = [e for e in evs if e["pid"] == 0 and e["tid"] == tid
                and e["name"] in ("placement", "queued", "prefill", "decode")]
        assert sum(e["dur"] for e in kids) == req["dur"], "children tile e2e"

    # exact boundaries: request A (gid 1) and B (gid (1<<48)|1)
    by_tid = {}
    for e in evs:
        if e["pid"] == 0 and e["ph"] == "X" and e["tid"] >= TID_REQ_BASE:
            by_tid.setdefault(e["tid"], {})[e["name"]] = e
    a = by_tid[TID_REQ_BASE + 1]
    assert (a["request"]["ts"], a["request"]["dur"]) == (0, 5 * TICK)
    assert [(a[n]["ts"], a[n]["dur"]) for n in ("placement", "queued", "prefill", "decode")] \
        == [(0, TICK), (TICK, TICK), (2 * TICK, TICK), (3 * TICK, 2 * TICK)]
    b = by_tid[TID_REQ_BASE + GID_B]
    assert (b["request"]["ts"], b["request"]["dur"]) == (7 * TICK, 3 * TICK)
    assert [(b[n]["ts"], b[n]["dur"]) for n in ("placement", "queued", "prefill", "decode")] \
        == [(7 * TICK, TICK), (8 * TICK, 0), (8 * TICK, TICK), (9 * TICK, TICK)]

    migs = [e for e in evs if e["name"] == "migration"]
    assert len(migs) == 1 and migs[0]["ph"] == "X"
    assert (migs[0]["ts"], migs[0]["dur"]) == (6 * TICK, TICK)
    assert migs[0]["args"]["tokens"] == 4 and migs[0]["args"]["adopted"] is True
    assert any(e["pid"] == 2 and e["name"] == "request" for e in evs), \
        "replica lifecycles appear under their own pids"


# ---------------------------------------------------------------------------
# verify_trace.py --fleet self-test: accept the valid doc, reject corruptions
# ---------------------------------------------------------------------------

def run_validator(doc, extra):
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(doc, f)
        path = f.name
    try:
        return subprocess.run(
            [sys.executable, VERIFY, path, "--fleet", *extra],
            capture_output=True, text=True)
    finally:
        os.unlink(path)


def find(evs, **kv):
    for i, e in enumerate(evs):
        if all(e.get(k) == v for k, v in kv.items()):
            return i
    raise AssertionError(f"no event matching {kv}")


def corruptions(doc):
    """Yield (label, corrupted-doc, expected-diagnostic-substring)."""
    def fresh():
        return copy.deepcopy(doc)

    d = fresh()
    evs = d["traceEvents"]
    evs[find(evs, name="process_name", pid=0)]["args"]["name"] = "router"
    yield "pid-0 rename", d, "must be named puzzle-router"

    d = fresh()
    evs = d["traceEvents"]
    evs[find(evs, name="routed")]["tid"] = 5
    yield "routed off the routing track", d, "expected pid 0 tid 0"

    d = fresh()
    evs = d["traceEvents"]
    del evs[find(evs, name="placement", pid=0, tid=TID_REQ_BASE + 1)]
    yield "finished request missing its placement stage", d, "lifecycle stages"

    d = fresh()
    evs = d["traceEvents"]
    evs[find(evs, name="queued", pid=0, tid=TID_REQ_BASE + 1)]["dur"] = 1500
    yield "stage chain broken (queued overruns)", d, "expected 2500"

    d = fresh()
    d["traceEvents"] = [e for e in d["traceEvents"]
                        if not (e["pid"] == 1 and e["tid"] == TID_REQ_BASE + 1
                                and e["ph"] == "X")]
    yield "replica-side track removed", d, "has no track on pid 1"

    d = fresh()
    evs = d["traceEvents"]
    evs[find(evs, name="request", pid=0, tid=TID_REQ_BASE + 1)]["args"]["replica"] = 1
    yield "id high bits contradict the replica arg", d, "does not encode replica"

    d = fresh()
    d["traceEvents"].append(instant("migration_unpaired", 6 * TICK, 0, 1, {"mig": 9}))
    yield "unpaired migration marker", d, "unpaired migration"

    d = fresh()
    evs = d["traceEvents"]
    del evs[find(evs, name="migration")]["args"]["adopted"]
    yield "migration span missing an arg", d, "missing arg"

    d = fresh()
    evs = d["traceEvents"]
    evs[find(evs, name="decode", pid=0, tid=TID_REQ_BASE + 1)]["dur"] = -1
    yield "negative span duration", d, "dur >= 0"

    d = fresh()
    evs = d["traceEvents"]
    i = find(evs, name="request", pid=0, tid=TID_REQ_BASE + 1)
    evs.insert(i + 1, copy.deepcopy(evs[i]))
    yield "duplicate enclosing request span", d, "exactly one enclosing request"


# ---------------------------------------------------------------------------
# slo.rs transcription: fold_requests / burn_rates / burn_profiles
# ---------------------------------------------------------------------------

WINDOW_SHORT = 60_000_000
WINDOW_LONG = 300_000_000


def burn_profiles(virtual_clock):
    if virtual_clock:
        return [("lenient", 48 * TICK, 6 * TICK, 0.99),
                ("strict", 3 * TICK, TICK, 0.90)]
    return [("wall_lenient", 30_000_000, 5_000_000, 0.99),
            ("wall_strict", 1_000_000, 250_000, 0.90)]


def fold_requests(rings):
    merged = merge_logs(rings)
    gaps = {}
    for ts, ev in merged:
        if ev["ev"] == "token":
            e = gaps.setdefault(ev["id"], [ts, 0])
            e[1] = max(e[1], ts - e[0])
            e[0] = ts
    out = []
    for s in request_spans(merged):
        if s["reason"] is None or s["reason"] == "cancelled" or s["finish_us"] is None:
            continue
        start = s["route_us"] if s["route_us"] is not None else s["submit_us"]
        ttft = s["first_us"] - start if s["first_us"] is not None else None
        out.append((s["finish_us"], ttft, gaps.get(s["id"], (0, 0))[1]))
    return out


def met_by(profile, rec):
    _, ttft_budget, itl_budget, _ = profile
    finish, ttft, max_gap = rec
    return ttft is not None and ttft <= ttft_budget and max_gap <= itl_budget


def burn_rates(records, profiles, now):
    out = []
    for p in profiles:
        for window in (WINDOW_SHORT, WINDOW_LONG):
            lo = max(now - window, 0)
            inw = [r for r in records if lo < r[0] <= now]
            total, met = len(inw), sum(1 for r in inw if met_by(p, r))
            goodput = 1.0 if total == 0 else met / total
            burn = (1.0 - goodput) / (1.0 - p[3])
            out.append((p[0], window, total, met, goodput, burn))
    return out


def check_slo():
    # profiles_mirror_the_harness_budgets
    [(ln, lt, li, lo), (sn, st, si, so)] = burn_profiles(True)
    assert (ln, lt, li) == ("lenient", 48 * TICK, 6 * TICK)
    assert (sn, st, si) == ("strict", 3 * TICK, TICK)
    assert so < lo
    [(_, wt, wi, _), (_, xt, xi, _)] = burn_profiles(False)
    assert (wt, wi) == (30_000_000, 5_000_000) and (xt, xi) == (1_000_000, 250_000)

    # fold_measures_ttft_from_the_router_door_and_worst_gap
    ring = [
        (0, {"ev": "routed", "id": 1, "replica": 0, "matched": 0, "depth": 0,
             "reason": "load", "probes": [(0, 0)]}),
        (2 * TICK, {"ev": "submitted", "id": 1, "prompt": 4, "max_new": 4}),
        (3 * TICK, {"ev": "admitted", "id": 1, "lane": 0, "hit": False, "matched": 0}),
        (5 * TICK, {"ev": "first_token", "id": 1}),
        (5 * TICK, {"ev": "token", "id": 1, "tok": 7}),
        (6 * TICK, {"ev": "token", "id": 1, "tok": 8}),
        (9 * TICK, {"ev": "token", "id": 1, "tok": 9}),
        (9 * TICK, {"ev": "finished", "id": 1, "reason": "eos", "tokens": 3}),
        (9 * TICK, {"ev": "submitted", "id": 2, "prompt": 4, "max_new": 4}),
    ]
    recs = fold_requests([ring])
    assert recs == [(9 * TICK, 5 * TICK, 3 * TICK)], recs

    # cancelled_requests_are_excluded
    ring = [(0, {"ev": "submitted", "id": 1, "prompt": 4, "max_new": 4}),
            (TICK, {"ev": "finished", "id": 1, "reason": "cancelled", "tokens": 0})]
    assert fold_requests([ring]) == []

    # burn_is_miss_fraction_over_error_budget
    p = ("t", 100, 100, 0.9)
    recs = [(1_000 + i, 500 if i == 0 else 50, 0) for i in range(4)]
    rates = burn_rates(recs, [p], 10_000)
    assert len(rates) == 2
    for _, _, total, met, goodput, burn in rates:
        assert (total, met) == (4, 3)
        assert abs(goodput - 0.75) < 1e-12 and abs(burn - 2.5) < 1e-12
    old = [(10, 500, 0)]
    _, _, total, _, goodput, burn = burn_rates(old, [p], WINDOW_SHORT + 1_000)[0]
    assert (total, goodput, burn) == (0, 1.0, 0.0), "no traffic is not an outage"

    # window boundary semantics: finish == now - window is OUT (the lower
    # bound is exclusive — with now inside the first window the bound
    # saturates to 0 and a tick-0 finish is excluded), finish == now is IN
    now = WINDOW_SHORT + 5_000
    edge = [(now - WINDOW_SHORT, 0, 0), (now - WINDOW_SHORT + 1, 0, 0), (now, 0, 0)]
    assert burn_rates(edge, [p], now)[0][2] == 2
    assert burn_rates([(0, 0, 0)], [p], 2 * TICK)[0][2] == 0, \
        "a tick-0 finish sits on the excluded saturated bound"
    # records without a first token never meet any budget
    assert not met_by(p, (TICK, None, 0))

    # fuzz fold_requests against directly generated boundaries
    for seed in range(5):
        rng = random.Random(seed)
        rings = [[] for _ in range(3)]
        expected = []
        for i in range(1, 120):
            t = rng.randrange(0, 1_000) * TICK
            routed = rng.random() < 0.5
            if routed:
                rings[0].append((t, {"ev": "routed", "id": i, "replica": 0,
                                     "matched": 0, "depth": 0, "reason": "load",
                                     "probes": []}))
            submit = t + rng.randrange(0, 3) * TICK
            ring = rings[1 + i % 2]
            ring.append((submit, {"ev": "submitted", "id": i, "prompt": 4, "max_new": 8}))
            if rng.random() < 0.15:
                continue  # never finishes: must not fold
            admit = submit + rng.randrange(0, 4) * TICK
            ring.append((admit, {"ev": "admitted", "id": i, "lane": 0,
                                 "hit": False, "matched": 0}))
            if rng.random() < 0.1:
                ring.append((admit, {"ev": "finished", "id": i,
                                     "reason": "cancelled", "tokens": 0}))
                continue  # cancelled: must not fold
            first = admit + rng.randrange(0, 5) * TICK
            ring.append((first, {"ev": "first_token", "id": i}))
            tok_ts, cur = [], first
            for _ in range(rng.randrange(1, 6)):
                ring.append((cur, {"ev": "token", "id": i, "tok": 1}))
                tok_ts.append(cur)
                cur += rng.randrange(0, 7) * TICK
            finish = tok_ts[-1]
            ring.append((finish, {"ev": "finished", "id": i, "reason": "eos",
                                  "tokens": len(tok_ts)}))
            gap = max((b - a for a, b in zip(tok_ts, tok_ts[1:])), default=0)
            expected.append((finish, first - (t if routed else submit), gap))
        for ring in rings:
            ring.sort(key=lambda r: r[0])
        got = sorted(fold_requests(rings))
        assert got == sorted(expected), f"seed {seed}: fold mismatch"


# ---------------------------------------------------------------------------

def main():
    router, replicas = sample_fleet()
    doc = merge_fleet(router, replicas)
    check_anchors(doc)
    print("1. merge_fleet transcription matches the export.rs unit-test "
          "expectations (pid naming, tiling, exact stitched boundaries, "
          "paired migration span) ✓")

    r = run_validator(doc, ["--expect-prefix-hit", "--expect-migration"])
    assert r.returncode == 0, f"validator rejected the valid fleet doc:\n{r.stderr}"
    assert "2 replicas, 2 routed, 1 migrations" in r.stdout, r.stdout
    print(f"2. verify_trace.py --fleet accepts the generated document "
          f"({r.stdout.strip().split(': ok: ')[1]}) ✓")

    n = 0
    for label, bad, want in corruptions(doc):
        r = run_validator(bad, [])
        assert r.returncode == 1, f"{label}: validator accepted a corrupted doc"
        assert want in r.stderr, \
            f"{label}: wrong diagnostic (wanted {want!r}):\n{r.stderr}"
        n += 1
    print(f"3. all {n} corruptions rejected with the intended diagnostic ✓")

    check_slo()
    print("4. obs::slo transcription: unit-test expectations, window "
          "boundary semantics, and 5-seed fold fuzz (~500 lifecycles) all "
          "exact ✓")
    print("all fleet-trace cross-checks passed")


if __name__ == "__main__":
    main()
