//! Benchmark harness (criterion is unavailable offline; hand-rolled
//! timing with warmup + repetitions). One bench per paper table/figure
//! hot path plus the L3 micro-benchmarks driven in the §Perf pass:
//!
//!   mip_solve_paper_scale   — Table 13 / Fig 8: MIP at Llama-70B scale (80 layers)
//!   mip_solve_tiny          — search latency at this repo's scale
//!   serving_decode_step     — Table 3: engine decode-step latency / throughput
//!   serving_prefill         — Table 3: prefill latency
//!   serving_prefill_chunked — SLO-aware budgeted prefill vs inline (byte-identical)
//!   block_chain_forward     — Fig 5/6: full-model chained forward
//!   replace1_scoring        — §4.2 scoring pass over one batch
//!   kvcache_ops             — §6 paged-manager admit/grow/release
//!   simplex_pivots          — LP substrate
//!   tensor_matmul / jacobi_svd — host-side math substrates
//!
//! Run: cargo bench   (hermetic: pure-Rust reference backend)

use std::time::Instant;

use puzzle::arch::{Arch, SearchSpace};
use puzzle::config::TinyManifest;
use puzzle::data::{corpus::sample_sequence, Batcher, CorpusMix, World};
use puzzle::mip::{self, Constraints, Lp};
use puzzle::model::CompiledModel;
use puzzle::perf::{CostTable, HwProfile, Scenario};
use puzzle::runtime::{share, Backend, RefBackend};
use puzzle::scoring::{self, Metric, ScoreTable};
use puzzle::serving::kvcache::{PageCfg, PagedKvManager};
use puzzle::serving::{EngineConfig, GenRequest};
use puzzle::tensor::{svd::svd, Tensor};
use puzzle::util::{Json, Rng};
use puzzle::weights::store::init_parent;

struct Bench {
    rows: Vec<(String, f64, String)>,
}

impl Bench {
    fn time<F: FnMut()>(&mut self, name: &str, note: &str, reps: usize, mut f: F) {
        f(); // warmup
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("{name:<28} {:>12.3} ms   {note}", per * 1e3);
        self.rows.push((name.to_string(), per, note.to_string()));
    }
}

fn synthetic_scores(space: &SearchSpace, n_layers: usize) -> ScoreTable {
    let mut t = ScoreTable { metric_name: "bench".into(), ..Default::default() };
    let mut rng = Rng::new(1);
    for l in 0..n_layers {
        for a in &space.attn {
            t.set(l, "attn", &a.name(), rng.f64() * 0.2);
        }
        for f in &space.ffn {
            t.set(l, "ffn", &f.name(), rng.f64() * 0.3);
        }
    }
    t
}

fn main() {
    let mut b = Bench { rows: vec![] };
    println!("== puzzle bench suite (hand-rolled harness) ==");

    // ---------------- pure-rust substrates ----------------
    let mut rng = Rng::new(0);
    let a = Tensor::randn(&[128, 128], 1.0, &mut rng);
    let c = Tensor::randn(&[128, 128], 1.0, &mut rng);
    b.time("tensor_matmul_128", "host-side 128x128 GEMM", 50, || {
        let _ = a.matmul(&c);
    });
    let m = Tensor::randn(&[48, 32], 1.0, &mut rng);
    b.time("jacobi_svd_48x32", "low-rank baseline substrate", 5, || {
        let _ = svd(&m);
    });

    // simplex on a mid-size LP
    let mut lp = Lp::new(200);
    let mut r = Rng::new(2);
    for j in 0..200 {
        lp.obj[j] = r.f64();
    }
    for g in 0..20 {
        lp.add_eq((0..10).map(|k| (g * 10 + k, 1.0)).collect(), 1.0);
    }
    lp.add_le((0..200).map(|j| (j, 0.5 + r.f64())).collect(), 12.0);
    b.time("simplex_200var", "LP relaxation, 200 vars / 21 rows", 20, || {
        let _ = lp.solve();
    });

    // hermetic backend: in-memory manifest + rust interpreter
    let shared = share(RefBackend::new(TinyManifest::synthetic()));
    let be: &dyn Backend = &*shared;
    let cfg = be.man().cfg.clone();

    // MIP at the paper's Llama-70B scale: 80 layers (combo count follows
    // this config's head count; paper = 54/layer)
    {
        let n_layers = 80;
        let space = SearchSpace::full(cfg.n_heads as u32);
        let scores = synthetic_scores(&space, n_layers);
        let hw = HwProfile::h100_fp8();
        let sc = Scenario { prefill: 2048, decode: 2048, batch: 64 };
        let ct = CostTable::modeled(be.man(), &hw, &sc);
        let parent_tp = {
            let mut t = 0.0;
            for _ in 0..n_layers {
                t += ct.attn["gqa_r1"].0 + ct.ffn["r100"].0;
            }
            (sc.batch * sc.decode) as f64 / t
        };
        let cons = Constraints { throughput_min: Some(parent_tp * 1.8), ..Default::default() };
        b.time(
            "mip_solve_paper_scale",
            "80 layers (Llama-70B depth), <1s target",
            3,
            || {
                let _ = mip::search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0);
            },
        );
    }

    // ---------------- backend-executed benches ----------------
    let mut rng = Rng::new(7);
    let mut store = init_parent(be.man(), &mut rng);
    let space = SearchSpace::full(cfg.n_heads as u32);
    let n_layers = cfg.n_layers;
    // populate the block library via the training-free §3.2 inits so the
    // scoring bench covers the full variant set
    for job in puzzle::bld::decoupled_jobs(&space, n_layers) {
        puzzle::bld::init_job_weights(be.man(), &mut store, &job, None).unwrap();
    }
    let store = store;
    let arch = Arch::parent(n_layers);
    let world = World::new(1, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();

    // MIP at this repo's scale with the real cost model
    {
        let hw = HwProfile::h100_fp8();
        let sc = Scenario { prefill: cfg.s_prefill, decode: cfg.s_prefill, batch: 64 };
        let ct = CostTable::modeled(be.man(), &hw, &sc);
        let scores = synthetic_scores(&space, n_layers);
        let parent_tp = ct.arch_throughput(&arch);
        let cons = Constraints { throughput_min: Some(parent_tp * 1.8), ..Default::default() };
        b.time("mip_solve_tiny", "real cost table, tiny config", 5, || {
            let _ = mip::search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0);
        });
    }

    // full-model chained forward (Fig 5/6 inner loop)
    {
        let model = CompiledModel::assemble(be.man(), &store, &arch).unwrap();
        let mut batcher = Batcher::new(world.clone(), mix.clone(), cfg.b_train, cfg.s_train, 3);
        let batch = batcher.next_batch();
        b.time("block_chain_forward", "parent fwd, train shape", 10, || {
            let _ = model.forward(be, "train", &batch.inputs, batch.b, batch.s).unwrap();
        });
    }

    // replace-1-block scoring pass (§4.2)
    {
        let mut batcher = Batcher::new(world.clone(), mix.clone(), cfg.b_train, cfg.s_train, 4);
        let batches = vec![batcher.next_batch()];
        b.time("replace1_scoring", "full library x 1 batch, KL metric", 2, || {
            let _ = scoring::score_library(be, &store, &space, &batches, Metric::Kl).unwrap();
        });
    }

    // serving: prefill + decode step (Table 3 inner loops)
    {
        b.time("serving_prefill", "1 prompt through the engine", 5, || {
            let mut eng = EngineConfig::new().build(shared.clone(), &store, &arch).unwrap();
            let mut r2 = Rng::new(5);
            let prompt = sample_sequence(&world, &mix, 16, &mut r2);
            eng.submit(GenRequest::new(prompt, 1)).unwrap();
            let _ = eng.run_to_completion().unwrap();
        });
        let note = format!("{} seqs x 16 new tokens", cfg.b_decode);
        b.time("serving_decode_16tok", &note, 3, || {
            let mut eng = EngineConfig::new().build(shared.clone(), &store, &arch).unwrap();
            let mut r2 = Rng::new(6);
            for _ in 0..cfg.b_decode {
                let prompt = sample_sequence(&world, &mix, 8, &mut r2);
                eng.submit(GenRequest::new(prompt, 16)).unwrap();
            }
            let _ = eng.run_to_completion().unwrap();
        });
    }

    // SLO-aware chunked prefill: the same oversubscribed request set
    // through an inline-prefill engine and a budgeted one — the budgeted
    // run spreads prompt ingestion over steps (bounded per-step work)
    // and must reproduce every stream byte-for-byte
    {
        let mut r2 = Rng::new(13);
        let reqs: Vec<GenRequest> = (0..cfg.b_decode * 3)
            .map(|_| {
                let plen = r2.range(6, cfg.s_prefill.min(32));
                GenRequest::new(sample_sequence(&world, &mix, plen, &mut r2), 12)
            })
            .collect();
        let run = |budget: Option<usize>| {
            let mut ec = EngineConfig::new();
            if let Some(t) = budget {
                ec = ec.prefill_budget(t);
            }
            let mut eng = ec.build(shared.clone(), &store, &arch).unwrap();
            for r in &reqs {
                eng.submit(r.clone()).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> =
                eng.run_to_completion().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();
            out.sort();
            (out, eng.metrics.prefill_chunk_passes)
        };
        let mut plain = (Vec::new(), 0usize);
        b.time("serving_prefill_inline", "oversubscribed seqs, inline prefills", 3, || {
            plain = run(None);
        });
        let mut chunked = (Vec::new(), 0usize);
        b.time("serving_prefill_chunked", "same seqs, 8-token step budget", 3, || {
            chunked = run(Some(8));
        });
        assert_eq!(plain.0, chunked.0, "budgeted chunked prefill must not change any stream");
        assert!(chunked.1 > 0 && plain.1 == 0, "chunk passes must come only from the budget");
        println!(
            "chunked prefill: byte-identical outputs, {} chunk passes at budget 8",
            chunked.1
        );
    }

    // prefix cache: 8 sequences sharing a 24-token system prompt — the
    // shared prefix prefills once (cold retention), every later request
    // imports the retained K/V rows and prefills only its suffix
    {
        let mut r2 = Rng::new(31);
        let sys = sample_sequence(&world, &mix, 23, &mut r2);
        let prompts: Vec<Vec<u32>> = (0..8)
            .map(|_| {
                let mut p = sys.clone();
                p.extend(sample_sequence(&world, &mix, 3, &mut r2));
                p
            })
            .collect();
        let mut saved = 0usize;
        let mut hits = 0usize;
        b.time("prefix_reuse_8seq", "8 seqs sharing a 24-tok system prompt", 3, || {
            let mut eng = EngineConfig::new()
                .page_len(8)
                .prefix_cache(true, 8 << 20)
                .build(shared.clone(), &store, &arch)
                .unwrap();
            for p in &prompts {
                eng.submit(GenRequest::new(p.clone(), 4)).unwrap();
            }
            let _ = eng.run_to_completion().unwrap();
            saved = eng.metrics.prefix_tokens_saved;
            hits = eng.metrics.prefix_hits;
        });
        assert!(hits > 0 && saved > 0, "shared prompts must hit the prefix cache");
        println!("prefix cache: {hits} hits, {saved} prefill tokens saved across 8 shared-prompt sequences");
    }

    // serving perf trajectory: a continuous-batching run (3x oversubscribed
    // slots) whose throughput and latency percentiles are persisted to
    // BENCH_serving.json so future PRs can diff serving perf.
    {
        let mut eng = EngineConfig::new().build(shared.clone(), &store, &arch).unwrap();
        let mut r2 = Rng::new(11);
        let n_req = cfg.b_decode * 3;
        for _ in 0..n_req {
            let prompt = sample_sequence(&world, &mix, 8, &mut r2);
            eng.submit(GenRequest::new(prompt, 16)).unwrap();
        }
        let _ = eng.run_to_completion().unwrap();
        let m = &eng.metrics;
        let j = Json::from_pairs(vec![
            ("requests", Json::num(m.requests_completed as f64)),
            ("generated_tokens", Json::num(m.generated_tokens as f64)),
            ("gen_tok_per_s", Json::num(m.gen_throughput())),
            ("total_tok_per_s", Json::num(m.total_throughput())),
            ("p50_ttft_ms", Json::num(m.p50_ttft() * 1e3)),
            ("p95_ttft_ms", Json::num(m.p95_ttft() * 1e3)),
            ("p50_e2e_ms", Json::num(m.p50_e2e() * 1e3)),
            ("p95_e2e_ms", Json::num(m.p95_e2e() * 1e3)),
            ("overhead_frac", Json::num(m.overhead_frac())),
        ]);
        std::fs::write("BENCH_serving.json", j.to_pretty()).unwrap();
        println!("serving perf -> BENCH_serving.json ({:.1} gen tok/s, p95 ttft {:.2} ms)",
            m.gen_throughput(), m.p95_ttft() * 1e3);
    }

    // speculative decoding round-trip: child drafts, parent verifies
    // (specdec). The self-drafted run bounds the machinery's overhead and
    // must amortize > 1 token per parent forward — the whole point.
    {
        use puzzle::serving::SamplingParams;
        use puzzle::specdec::{SpecConfig, SpecSession};
        let parent_arch = Arch::parent(n_layers);
        let mut r2 = Rng::new(21);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|_| sample_sequence(&world, &mix, 8, &mut r2)).collect();
        let mut agg = (0usize, 0usize); // (tokens, parent passes)
        b.time("specdec_selfdraft_4x32tok", "k=4, parent as its own drafter", 2, || {
            let mut sess = SpecSession::new(
                shared.clone(),
                &store,
                &parent_arch,
                &store,
                &parent_arch,
                SpecConfig::default(),
            )
            .unwrap();
            agg = (0, 0);
            for p in &prompts {
                let r = sess.generate(p, 32, SamplingParams::greedy()).unwrap();
                agg.0 += r.tokens.len();
                agg.1 += r.parent_passes;
            }
        });
        let tpp = agg.0 as f64 / agg.1.max(1) as f64;
        println!("specdec amortization: {} tokens / {} parent passes = {tpp:.2} tok/pass", agg.0, agg.1);
        assert!(tpp > 1.0, "speculative decoding must amortize > 1 token per parent forward");
    }

    // batched speculation: the same N=4 requests at once, sharing the
    // engines' decode lanes with the fused multi-token verify — compare
    // against the sequential session bench above
    {
        use puzzle::serving::SamplingParams;
        use puzzle::specdec::{SpecBatch, SpecConfig, SpecRequest, SpecSession};
        let parent_arch = Arch::parent(n_layers);
        let mut r2 = Rng::new(21);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|_| sample_sequence(&world, &mix, 8, &mut r2)).collect();
        let mut seq_tokens = 0usize;
        b.time("specdec_sequential_4seq", "4 sequences one-by-one, k=4", 2, || {
            let mut sess = SpecSession::new(
                shared.clone(),
                &store,
                &parent_arch,
                &store,
                &parent_arch,
                SpecConfig::default(),
            )
            .unwrap();
            seq_tokens = 0;
            for p in &prompts {
                let r = sess.generate(p, 32, SamplingParams::greedy()).unwrap();
                seq_tokens += r.tokens.len();
            }
        });
        let mut agg = (0usize, 0usize); // (tokens, per-lane parent passes)
        b.time("specdec_batched_4seq", "same 4 sequences batched, k=4", 2, || {
            let mut batch = SpecBatch::new(
                shared.clone(),
                &store,
                &parent_arch,
                &store,
                &parent_arch,
                SpecConfig::default(),
            )
            .unwrap();
            let reqs: Vec<SpecRequest> =
                prompts.iter().map(|p| SpecRequest::new(p.clone(), 32)).collect();
            agg = (0, 0);
            for r in batch.generate_many(&reqs).unwrap() {
                agg.0 += r.tokens.len();
                agg.1 += r.parent_passes;
            }
        });
        assert_eq!(agg.0, seq_tokens, "batched and sequential runs must emit the same tokens");
        let tpp = agg.0 as f64 / agg.1.max(1) as f64;
        println!("batched specdec amortization: {} tokens / {} parent passes = {tpp:.2} tok/pass", agg.0, agg.1);
        assert!(tpp > 1.0, "batched speculation must amortize > 1 token per parent pass");
        let seq = b.rows.iter().find(|(n, _, _)| n == "specdec_sequential_4seq").map(|(_, p, _)| *p).unwrap();
        let bat = b.rows.iter().find(|(n, _, _)| n == "specdec_batched_4seq").map(|(_, p, _)| *p).unwrap();
        println!("batched vs sequential wall: {:.1} ms vs {:.1} ms ({:.2}x)", bat * 1e3, seq * 1e3, seq / bat.max(1e-12));
    }

    // workload harness: a seeded multi-turn trace replayed closed-loop
    // against the prefix-cache engine — the end-to-end serving hot path
    // (admission, chunked prefill, decode, finish-time retention, hits on
    // generated-origin rows) under a realistic arrival process
    {
        use puzzle::workload::{replay, MixKind, Server, TraceSpec};
        let trace =
            TraceSpec::small(MixKind::MultiTurn, 7).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
        let mut gen_hits = 0usize;
        let mut ticks = 0usize;
        b.time("workload_multiturn_replay", "6 conversations x 3 turns, prefix cache", 2, || {
            let mut eng = EngineConfig::new()
                .kv_budget_bytes(16 << 20)
                .page_len(4)
                .prefix_cache(true, 8 << 20)
                .build(shared.clone(), &store, &arch)
                .unwrap();
            let run = replay(&trace, &mut Server::Engine(&mut eng), "prefix_cache").unwrap();
            gen_hits = run.metrics.prefix_gen_hits;
            ticks = run.ticks;
        });
        assert!(gen_hits > 0, "multi-turn prompts must hit segments retained from generated tokens");
        println!("workload replay: {ticks} virtual ticks, {gen_hits} generated-origin prefix hits");
    }

    // paged KV manager ops (§6)
    {
        let mgr_cfg = PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: 1 << 24 };
        b.time("kvcache_ops", "admit+grow x64 + release, 8 seqs", 50, || {
            let mut mgr = PagedKvManager::new(be.man(), &arch, mgr_cfg.clone());
            for s in 0..8u64 {
                mgr.admit(s, 16);
                for _ in 0..64 {
                    mgr.grow(s);
                }
            }
            for s in 0..8u64 {
                mgr.release(s);
            }
        });
    }

    println!("\n{} benches complete", b.rows.len());
    // paper-shape sanity: MIP at paper scale must be sub-second (the paper:
    // "high-quality solutions within seconds" via python-mip)
    if let Some((_, per, _)) = b.rows.iter().find(|(n, _, _)| n == "mip_solve_paper_scale") {
        assert!(*per < 5.0, "paper-scale MIP too slow: {per}s");
        println!("paper-shape check: 80x54 MIP solves in {:.2}s (paper: seconds) ✓", per);
    }
}
